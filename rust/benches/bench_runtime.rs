//! E7 — PJRT execute cost per artifact (compile excluded; compile times
//! reported as notes) and the pallas-vs-plain-jnp ablation twin.
//! Requires `make artifacts`; prints a skip note otherwise.
//!
//! Run: `cargo bench --bench bench_runtime`

use wagener_hull::benchkit::{Bencher, Report};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::runtime::{ArtifactRegistry, HullExecutor};

fn main() {
    let b = Bencher::default();
    let mut report = Report::new("E7: PJRT artifact execution");
    let reg = match ArtifactRegistry::load("artifacts") {
        Ok(r) => r,
        Err(e) => {
            report.note(format!("SKIPPED: {e:#} (run `make artifacts`)"));
            report.finish();
            return;
        }
    };
    let exe = HullExecutor::new(reg).unwrap();

    // hood artifacts (single request, upper hull only)
    for name in ["hood_n64", "hood_n256", "hood_jnp_n256"] {
        let meta = exe.registry().get(name).unwrap().clone();
        let pts = generate(Distribution::Disk, meta.n, 5);
        exe.run_hood(&meta, &pts).unwrap(); // compile once
        report.add(b.run(&format!("pjrt/{name}"), || {
            exe.run_hood(&meta, &pts).unwrap()
        }));
    }
    report.note("hood_n256 vs hood_jnp_n256 = pallas kernel vs plain-jnp ablation (E7)");

    // batched hull artifacts: per-request cost vs batch size
    for (name, b_reqs) in [("hull_n64_b1", 1usize), ("hull_n64_b8", 8)] {
        let meta = exe.registry().get(name).unwrap().clone();
        let reqs: Vec<Vec<_>> = (0..b_reqs)
            .map(|k| generate(Distribution::Disk, 60, k as u64))
            .collect();
        exe.run_hull(&meta, &reqs).unwrap();
        report.add(b.run_batched(&format!("pjrt/{name}/per_request"), b_reqs, || {
            exe.run_hull(&meta, &reqs).unwrap()
        }));
    }

    // native comparison at the same sizes
    for n in [64usize, 256] {
        let pts = generate(Distribution::Disk, n, 5);
        report.add(b.run(&format!("native/wagener_n{n}"), || {
            wagener_hull::wagener::full_hull(std::hint::black_box(&pts))
        }));
    }

    let stats = exe.stats();
    report.note(format!(
        "compiles={} total_compile_ms={:.0} executions={}",
        stats.compiles,
        stats.compile_ns as f64 / 1e6,
        stats.executions
    ));
    report.finish();
}
