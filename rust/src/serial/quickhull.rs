//! QuickHull (divide-and-conquer by farthest point) — expected O(n log n),
//! worst-case O(n²); included as the "fast in practice" baseline for E4.

use crate::geometry::point::Point;
use crate::geometry::predicates::{orient2d_value, Orientation};

/// Upper hull of x-sorted, distinct-x points.
pub fn upper_hull(points: &[Point]) -> Vec<Point> {
    if points.len() <= 2 {
        return points.to_vec();
    }
    let a = points[0];
    let b = *points.last().unwrap();
    let mut out = vec![a];
    recurse(points, a, b, &mut out);
    out.push(b);
    out
}

fn recurse(points: &[Point], a: Point, b: Point, out: &mut Vec<Point>) {
    // farthest point strictly above chord a->b
    let mut best: Option<(f64, Point)> = None;
    for &p in points {
        if p == a || p == b || p.x <= a.x || p.x >= b.x {
            continue;
        }
        let v = orient2d_value(a, b, p);
        if v > 0.0 {
            match best {
                Some((bv, _)) if bv >= v => {}
                _ => best = Some((v, p)),
            }
        }
    }
    if let Some((_, m)) = best {
        recurse(points, a, m, out);
        out.push(m);
        recurse(points, m, b, out);
    }
}

/// Full hull (upper, lower) via y-negation.
pub fn full_hull(points: &[Point]) -> (Vec<Point>, Vec<Point>) {
    let upper = upper_hull(points);
    let neg: Vec<Point> = points.iter().map(|p| Point::new(p.x, -p.y)).collect();
    let lower = upper_hull(&neg)
        .into_iter()
        .map(|p| Point::new(p.x, -p.y))
        .collect();
    (upper, lower)
}

/// Note: `orient2d_value`'s sign is exact, so the farthest-point selection
/// may differ from an exact-arithmetic QuickHull only between two points at
/// nearly identical heights — which cannot change the final hull: the
/// recursion keeps every point strictly above each chord.
const _DOC: Orientation = Orientation::Left;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::serial::monotone_chain;

    #[test]
    fn matches_monotone_chain() {
        for dist in Distribution::ALL {
            for seed in [1, 2] {
                let pts = generate(dist, 128, seed);
                assert_eq!(
                    upper_hull(&pts),
                    monotone_chain::upper_hull(&pts),
                    "{} {seed}",
                    dist.name()
                );
            }
        }
    }

    #[test]
    fn full_hull_matches() {
        let pts = generate(Distribution::Disk, 200, 3);
        let (u, l) = full_hull(&pts);
        let (mu, ml) = monotone_chain::full_hull(&pts);
        assert_eq!(u, mu);
        assert_eq!(l, ml);
    }

    #[test]
    fn tiny_inputs() {
        let p = Point::new(0.1, 0.2);
        let q = Point::new(0.9, 0.8);
        assert_eq!(upper_hull(&[p]), vec![p]);
        assert_eq!(upper_hull(&[p, q]), vec![p, q]);
    }
}
