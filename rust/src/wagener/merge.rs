//! Host implementation of one match-and-merge: the paper's six `mam`
//! phases over a 2d-slot block pair, with the exact sampling structure of
//! the CUDA kernel (d1 × d2 thread lattice).
//!
//! This is the semantic single source of truth for the phases; the PRAM
//! execution (pram_exec.rs) and the Pallas kernel mirror it one-to-one.
//!
//! Perf note (§Perf P1): on a sequential host the "parallel for all x"
//! phases collapse to *lazy right-to-left scans* — mam3 only needs
//! `f(i_x, tangent(i_x))` for the x's it actually inspects before finding
//! k0, so the per-sample tangent brackets (mam1+mam2) are computed on
//! demand instead of being materialized for every lattice column.  Same
//! predicates, same selection, no allocation.

use super::stage::stage_dims;
use super::tangent::{f, g, Code};
use crate::geometry::point::{Point, REMOTE};

/// Result of the tangent-search phases (block-relative indices).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tangent {
    /// touch corner in the P half, `[0, d)`.
    pub pidx: usize,
    /// touch corner in the Q half, `[d, 2d)`.
    pub qidx: usize,
}

/// mam1 + mam2 for one P sample: the tangent touch corner on H(Q) from
/// blk[i] (i live).  Bracket between Q samples of stride d1, then refine.
#[inline]
fn qexact_for(blk: &[Point], i: usize, d1: usize, d2: usize) -> usize {
    let d = d1 * d2;
    // mam1: max Q sample j_y = d + d1*y with g <= EQUAL
    let mut qsamp = d;
    for y in (0..d2).rev() {
        let j = d + d1 * y;
        if g(blk, i, j, d) <= Code::Equal {
            qsamp = j;
            break;
        }
    }
    // mam2: unique EQUAL within [qsamp, qsamp + d1)
    for t in 0..d1 {
        if g(blk, i, qsamp + t, d) == Code::Equal {
            return qsamp + t;
        }
    }
    unreachable!("tangent-from-point must exist on a non-empty hood")
}

/// Locate the common tangent of H(P), H(Q) stored in `blk` (length 2d),
/// via the paper's sampled phases mam1..mam5.  O(d) predicate evaluations
/// worst case, O(1) PRAM depth.  Q half must be non-empty.
pub fn find_tangent(blk: &[Point], d1: usize, d2: usize) -> Tangent {
    let d = d1 * d2;
    debug_assert_eq!(blk.len(), 2 * d);
    debug_assert!(blk[d].is_live(), "Q half must be non-empty");

    // mam3: k0 = max P sample with f(i_x, tangent(i_x)) <= EQUAL.  The f
    // codes along x read LOW* EQ HIGH*, so the first non-HIGH sample in a
    // right-to-left scan is the max — tangents computed lazily per probe.
    let mut k0 = 0;
    for x in (0..d1).rev() {
        let i = d2 * x;
        if blk[i].is_remote() {
            continue;
        }
        if f(blk, i, qexact_for(blk, i, d1, d2), d) <= Code::Equal {
            k0 = i;
            break;
        }
    }

    // mam4: for each exact candidate i = k0 + y, re-bracket on H(Q) with
    // the finer stride d2 (d1 samples).
    // mam5: the unique pair with g == f == EQUAL.
    for y in 0..d2 {
        let i = k0 + y;
        if blk[i].is_remote() {
            continue;
        }
        let mut qs2 = d;
        for x in (0..d1).rev() {
            let j = d + d2 * x;
            if g(blk, i, j, d) <= Code::Equal {
                qs2 = j;
                break;
            }
        }
        for t in 0..d2 {
            let j = qs2 + t;
            if g(blk, i, j, d) == Code::Equal && f(blk, i, j, d) == Code::Equal {
                return Tangent { pidx: i, qidx: j };
            }
        }
    }
    unreachable!("common tangent must exist for non-empty hood halves")
}

/// mam6: materialize H(P ∪ Q) from the tangent: blk[0..=pidx] ++
/// blk[qidx..2d) ++ REMOTE…  REMOTE-fills past pidx *before* the shifted
/// copy — the paper's published kernel leaves stale P corners alive when
/// `pidx + d - qoff < d - 1` (DESIGN.md §1.1); this fixes that.
pub fn apply_merge(blk: &[Point], t: Tangent, out: &mut [Point]) {
    let n2 = blk.len();
    debug_assert_eq!(out.len(), n2);
    out[..=t.pidx].copy_from_slice(&blk[..=t.pidx]);
    let keep = n2 - t.qidx;
    out[t.pidx + 1..t.pidx + 1 + keep].copy_from_slice(&blk[t.qidx..]);
    out[t.pidx + 1 + keep..].fill(REMOTE);
}

/// §Perf P2: direct chain merge for tiny blocks.  At d <= 4 the sampled
/// phases cost more than simply re-scanning the <= 8 live corners (and
/// under general position the result is identical); the first two stages
/// own half the pipeline's blocks, so this is the hottest spot.
#[inline]
fn merge_small_into(blk: &[Point], d: usize, out: &mut [Point]) {
    use crate::geometry::predicates::{orient2d, Orientation};
    let mut k = 0usize;
    for half in [&blk[..d], &blk[d..]] {
        for &p in half {
            if p.is_remote() {
                break;
            }
            while k >= 2 && orient2d(out[k - 2], p, out[k - 1]) != Orientation::Left {
                k -= 1;
            }
            out[k] = p;
            k += 1;
        }
    }
    out[k..].fill(REMOTE);
}

/// Merge one block pair into a caller-provided output slice (hot path —
/// no allocation).
pub fn merge_block_into(blk: &[Point], d1: usize, d2: usize, out: &mut [Point]) {
    let d = d1 * d2;
    debug_assert_eq!(blk.len(), 2 * d);
    if blk[d].is_remote() {
        // Q empty (input padding): the merged hood is H(P) verbatim.
        out.copy_from_slice(blk);
        return;
    }
    if d <= 4 {
        merge_small_into(blk, d, out);
        return;
    }
    let t = find_tangent(blk, d1, d2);
    apply_merge(blk, t, out);
}

/// Merge one block pair (allocating convenience wrapper).
pub fn merge_block(blk: &[Point], d1: usize, d2: usize) -> Vec<Point> {
    let mut out = vec![REMOTE; blk.len()];
    merge_block_into(blk, d1, d2, &mut out);
    out
}

/// Merge with explicit d (derives the paper's d1 × d2 lattice).
pub fn merge_block_d(blk: &[Point], d: usize) -> Vec<Point> {
    let (d1, d2) = stage_dims(d);
    merge_block(blk, d1, d2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::point::{pad_to_hood, sort_by_x};
    use crate::serial::monotone_chain;
    use crate::util::rng::Rng;

    fn random_block(rng: &mut Rng, d: usize, pmax: usize, qmax: usize) -> Vec<Point> {
        let np = rng.range_usize(1, pmax + 1);
        let nq = rng.range_usize(0, qmax + 1);
        let mut p: Vec<Point> = (0..np)
            .map(|_| Point::new(rng.f64() * 0.49, rng.f64()).quantize_f32())
            .collect();
        let mut q: Vec<Point> = (0..nq)
            .map(|_| Point::new(0.51 + rng.f64() * 0.49, rng.f64()).quantize_f32())
            .collect();
        sort_by_x(&mut p);
        sort_by_x(&mut q);
        p.dedup_by(|a, b| a.x == b.x);
        q.dedup_by(|a, b| a.x == b.x);
        let mut blk = pad_to_hood(&monotone_chain::upper_hull(&p), d);
        blk.extend(pad_to_hood(&monotone_chain::upper_hull(&q), d));
        blk
    }

    fn oracle_merge(blk: &[Point]) -> Vec<Point> {
        let live: Vec<Point> = blk.iter().copied().filter(|p| p.is_live()).collect();
        let mut out = monotone_chain::upper_hull(&live);
        out.resize(blk.len(), REMOTE);
        out
    }

    #[test]
    fn merge_matches_oracle_across_lattices() {
        let mut rng = Rng::new(61);
        for &d in &[2usize, 4, 8, 16, 32, 64] {
            let (d1, d2) = stage_dims(d);
            for _ in 0..60 {
                let blk = random_block(&mut rng, d, d, d);
                let got = merge_block(&blk, d1, d2);
                assert_eq!(got, oracle_merge(&blk), "d={d}");
            }
        }
    }

    #[test]
    fn merge_with_empty_q() {
        let mut rng = Rng::new(62);
        let blk = random_block(&mut rng, 8, 8, 0);
        assert!(blk[8].is_remote());
        let got = merge_block(&blk, 4, 2);
        assert_eq!(got, oracle_merge(&blk));
    }

    #[test]
    fn paper_bug_regression_far_left_p_far_right_q() {
        // H(P) full with tangent at its first corner, H(Q) tangent at its
        // last corner: the paper's mam6 would leave stale P corners.
        let p = vec![
            Point::new(0.00, 0.95),
            Point::new(0.10, 0.50),
            Point::new(0.20, 0.20),
            Point::new(0.30, 0.05),
        ];
        let q = vec![
            Point::new(0.60, 0.04),
            Point::new(0.70, 0.10),
            Point::new(0.80, 0.30),
            Point::new(0.90, 0.90),
        ];
        let mut blk = p.clone();
        blk.extend(q.clone());
        // both halves are already convex chains (steep descent / ascent)
        let t = find_tangent(&blk, 2, 2);
        assert_eq!((t.pidx, t.qidx), (0, 7));
        let got = merge_block(&blk, 2, 2);
        assert_eq!(got, oracle_merge(&blk));
        assert!(got[2].is_remote(), "stale P corner survived: {:?}", got);
    }

    #[test]
    fn tangent_is_brute_force_tangent() {
        use crate::geometry::predicates::left_of;
        let mut rng = Rng::new(63);
        for _ in 0..100 {
            let blk = random_block(&mut rng, 16, 16, 16);
            if blk[16].is_remote() {
                continue;
            }
            let t = find_tangent(&blk, 4, 4);
            for (o, pt) in blk.iter().enumerate() {
                if pt.is_live() && o != t.pidx && o != t.qidx {
                    assert!(
                        !left_of(blk[t.pidx], blk[t.qidx], *pt),
                        "corner {o} above tangent"
                    );
                }
            }
        }
    }

    #[test]
    fn into_variant_matches_allocating() {
        let mut rng = Rng::new(64);
        for _ in 0..40 {
            let blk = random_block(&mut rng, 16, 16, 16);
            let a = merge_block(&blk, 4, 4);
            let mut b = vec![REMOTE; 32];
            merge_block_into(&blk, 4, 4, &mut b);
            assert_eq!(a, b);
        }
    }
}
