"""L1: octagon interior-point prefilter as a Pallas kernel (+ jnp twin).

The GPU-filter stage of Carrasco et al. (and CudaChain's point-flagging
pass): before the hull kernel runs, drop every point strictly inside the
convex polygon of the 8 directional extremes (±x, ±y, ±(x+y), ±(x−y)) —
such points can never be hull vertices, so dense inputs shrink on-device
and the hull pipeline sees a fraction of the upload.

One kernel invocation filters one n-slot block (x-sorted, live-left-
justified, REMOTE-padded — the same layout every other kernel speaks):

  1. extremes  — a one-pass 8-way max reduction over the directional keys
                 [-x, -(x+y), -y, x-y, x, x+y, y, -(x-y)] (W, SW, S, SE,
                 E, NE, N, NW — ccw), ties broken to the FIRST occurrence
                 (``jnp.argmax``), matching the host filter's strict ``>``
                 scan bit for bit;
  2. flagging  — branch-free ``jnp.where``: a point is dropped iff it is
                 strictly left of every directed octagon edge.  Degenerate
                 edges (coincident consecutive extremes) auto-pass, which
                 is exactly the host's consecutive-dedup; the host's
                 "< 3 distinct corners" and "any right turn" passthrough
                 guards become scalar predicates folded into the flag;
  3. compaction — survivors scatter to ``cumsum(keep) - 1`` (prefix-sum
                 compaction), preserving x-sorted order; the tail is
                 REMOTE-filled, so the output is again a valid block.

The filter is *hull-preserving by construction* under the same
strict-inside rule as the host filter (boundary points are kept); the
exact host filter remains the safety oracle and the non-pjrt path.
Orientation determinants are f64 per the device convention (wagener.py);
the rust side property-tests device ≡ host ≡ off hull bit-identity.

Kernels MUST be lowered with interpret=True (see wagener.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import wagener
from .wagener import DET_DTYPE, LIVE_X_MAX, REMOTE_X, REMOTE_Y, _left_of

# Below this many live points the filter is a passthrough — mirrors
# rust/src/coordinator/request.rs::PREFILTER_MIN_POINTS.
PREFILTER_MIN_POINTS = 32

# Directional keys, ccw from W; the i-th extreme maximizes keys[:, i].
# Order matters: consecutive extremes are 45° apart, so the octagon edges
# (ext[i], ext[i+1 mod 8]) run counterclockwise.
_N_DIRS = 8


def _keys(pts: jnp.ndarray) -> jnp.ndarray:
    """(n, 2) -> (n, 8) directional keys in f64 (W SW S SE E NE N NW)."""
    x = pts[:, 0].astype(DET_DTYPE)
    y = pts[:, 1].astype(DET_DTYPE)
    return jnp.stack(
        [-x, -(x + y), -y, x - y, x, x + y, y, -(x - y)], axis=-1
    )


def octagon_extremes(pts: jnp.ndarray) -> jnp.ndarray:
    """The 8 directional extremes of the live points, ccw, (8, 2) f32.

    First occurrence wins a tie — identical to the host filter's strict
    ``>`` left-to-right scan.  REMOTE slots never win (keys -> -inf).
    """
    live = pts[:, 0] <= LIVE_X_MAX
    keys = jnp.where(live[:, None], _keys(pts), -jnp.inf)
    ext_idx = jnp.argmax(keys, axis=0)          # (8,), first max each dir
    return jnp.take(pts, ext_idx, axis=0)


def octagon_keep(pts: jnp.ndarray) -> jnp.ndarray:
    """Boolean keep mask: live and NOT strictly inside the extremes octagon.

    Folds in the host filter's passthrough guards as scalar predicates:
    fewer than PREFILTER_MIN_POINTS live points, fewer than 3 distinct
    octagon corners, or any right turn on the (deduped) octagon — in each
    case every live point is kept and the filter is the identity.
    """
    live = pts[:, 0] <= LIVE_X_MAX
    ext = octagon_extremes(pts)                 # (8, 2)
    nxt = jnp.roll(ext, -1, axis=0)             # edge i: ext[i] -> nxt[i]
    # Degenerate edge (coincident consecutive extremes): contributes no
    # constraint — the same polygon the host's consecutive-dedup builds.
    same = jnp.all(ext == nxt, axis=-1)         # (8,)
    # Host guard 1: < 3 distinct corners (circular run count).
    n_distinct = jnp.sum(~same)
    # Host guard 2: any right turn on the deduped octagon.  A weakly
    # convex ccw polygon has every vertex left-of-or-on every directed
    # edge, so "some corner strictly right of some non-degenerate edge"
    # is exactly the host's consecutive-triple right-turn test.
    right = _left_of(nxt[:, None, :], ext[:, None, :], ext[None, :, :])
    any_right = jnp.any(~same[:, None] & right)
    passthrough = (
        (jnp.sum(live) < PREFILTER_MIN_POINTS)
        | (n_distinct < 3)
        | any_right
    )
    # Strictly inside iff strictly left of every non-degenerate edge.
    left = _left_of(ext[:, None, :], nxt[:, None, :], pts[None, :, :])
    inside = jnp.all(same[:, None] | left, axis=0)  # (n,)
    return live & (passthrough | ~inside)


def compact(pts: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """Prefix-sum scatter compaction: survivors left-justified, in input
    order; the tail REMOTE-filled.  Scatter targets are unique, dropped
    slots scatter out of range (mode='drop'), so the write is race-free —
    the paper's divergence-free style."""
    n = pts.shape[0]
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    target = jnp.where(keep, pos, n)
    remote = jnp.stack(
        [
            jnp.full((n,), REMOTE_X, dtype=pts.dtype),
            jnp.full((n,), REMOTE_Y, dtype=pts.dtype),
        ],
        axis=-1,
    )
    return remote.at[target].set(pts, mode="drop")


def filter_block(pts: jnp.ndarray) -> jnp.ndarray:
    """Filter one n-slot block: (n, 2) -> (n, 2), survivors left-justified.

    Pure function of the block; shared verbatim by the pallas kernel body
    and the plain-jnp twin so both lower from one source of truth."""
    assert pts.ndim == 2 and pts.shape[1] == 2, pts.shape
    return compact(pts, octagon_keep(pts))


def _filter_kernel(pts_ref, out_ref):
    """Pallas body: one program filters the whole block (the reduction,
    flagging and compaction are each one fused vector pass)."""
    out_ref[...] = filter_block(pts_ref[...])


@jax.jit
def pallas_filter(pts: jnp.ndarray) -> jnp.ndarray:
    """Octagon prefilter over an (n, 2) block via pallas_call."""
    n = pts.shape[0]
    spec = pl.BlockSpec((n, 2), lambda b: (0, 0))
    return pl.pallas_call(
        _filter_kernel,
        grid=(1,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(pts.shape, pts.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(pts)


@jax.jit
def jnp_filter(pts: jnp.ndarray) -> jnp.ndarray:
    """Plain-jnp twin of :func:`pallas_filter` (differential test target)."""
    return filter_block(pts)


# re-export for tests/aot
enable_x64 = wagener.enable_x64
