//! Wagener's algorithm as explicit PE programs on the PRAM simulator.
//!
//! This is the paper-faithful execution: one kernel launch per stage,
//! `n/2` PEs in `n/(2d)` blocks of `d1 × d2`, shared arrays `hood`,
//! `newhood` (float2) and `scratch` (index) of size n, the six `mam`
//! phases as synchronous steps separated by barriers (`__syncthreads`).
//!
//! Deviations from the published CUDA listing (DESIGN.md §1.1):
//!   * mam3 guards its write with `y == 0` — the paper lets all d2 threads
//!     of a qualifying column write the same value, which is common-CRCW,
//!     not CREW; the simulator's conflict checker would (correctly) trip.
//!   * mam6 REMOTE-fills the lower half past `pindex` before the shifted
//!     copy (stale-corner bug fix).
//!   * phases idle on block pairs whose Q half is empty (input padding);
//!     the merged hood is then H(P) verbatim.
//!
//! Memory map (slot s holds a point at cells 2s, 2s+1):
//!   hood    cells [0,      2n)
//!   newhood cells [2n,     4n)
//!   scratch cells [4n,     5n)    (indices stored as f64; -1 = uninit)

use super::stage::stage_dims;
use super::tangent::Code;
use crate::geometry::point::{Point, REMOTE};
use crate::pram::{Counters, ExecMode, PeCtx, Pram, PramError};

/// Per-stage accounting snapshot (drives experiments E2 / E4).
#[derive(Clone, Debug)]
pub struct StageStats {
    pub d: usize,
    pub d1: usize,
    pub d2: usize,
    pub blocks: usize,
    pub pes: usize,
    pub steps: u64,
    pub work: u64,
    pub reads: u64,
    pub writes: u64,
    pub modeled_cycles: u64,
    pub ideal_cycles: u64,
}

/// Result of a full PRAM pipeline run.
#[derive(Clone, Debug)]
pub struct PramRun {
    pub hood: Vec<Point>,
    pub counters: Counters,
    pub per_stage: Vec<StageStats>,
}

struct Layout {
    n: usize,
}

impl Layout {
    fn hood(&self, slot: usize) -> usize {
        2 * slot
    }
    fn newhood(&self, slot: usize) -> usize {
        2 * self.n + 2 * slot
    }
    fn scratch(&self, slot: usize) -> usize {
        4 * self.n + slot
    }
}

fn rd_hood(ctx: &mut PeCtx<'_>, lay: &Layout, slot: usize) -> Point {
    let (x, y) = ctx.read_pair(lay.hood(slot));
    Point::new(x, y)
}

/// Device-side g: same semantics as tangent::g, reading through the PE
/// context so every access is cost-accounted.  `start` = block start slot,
/// `i` in [start, start+d), `j` in [start+d, start+2d).
fn g_dev(ctx: &mut PeCtx<'_>, lay: &Layout, start: usize, d: usize, i: usize, j: usize) -> Code {
    let p = rd_hood(ctx, lay, i);
    let q = rd_hood(ctx, lay, j);
    if p.is_remote() || q.is_remote() {
        return Code::High;
    }
    use crate::geometry::predicates::left_of;
    let at_end = j + 1 >= start + 2 * d;
    let nxt_raw = if at_end { q } else { rd_hood(ctx, lay, j + 1) };
    let q_next = if at_end || nxt_raw.is_remote() { q.below() } else { nxt_raw };
    if left_of(p, q, q_next) {
        return Code::Low;
    }
    let q_prev = if j == start + d { q.below() } else { rd_hood(ctx, lay, j - 1) };
    if left_of(p, q, q_prev) {
        Code::High
    } else {
        Code::Equal
    }
}

/// Device-side f (see tangent::f).
fn f_dev(ctx: &mut PeCtx<'_>, lay: &Layout, start: usize, d: usize, i: usize, j: usize) -> Code {
    let p = rd_hood(ctx, lay, i);
    let q = rd_hood(ctx, lay, j);
    if p.is_remote() || q.is_remote() {
        return Code::High;
    }
    use crate::geometry::predicates::left_of;
    let at_end = i + 1 >= start + d;
    let nxt_raw = if at_end { p } else { rd_hood(ctx, lay, i + 1) };
    let p_next = if at_end || nxt_raw.is_remote() { p.below() } else { nxt_raw };
    if left_of(p, q, p_next) {
        return Code::Low;
    }
    let p_prev = if i == start { p.below() } else { rd_hood(ctx, lay, i - 1) };
    if left_of(p, q, p_prev) {
        Code::High
    } else {
        Code::Equal
    }
}

/// Execute the full pipeline on a fresh PRAM machine (strict CREW: any
/// write-write conflict — only possible when the input violates the
/// paper's general-position assumption — is an error).
///
/// `points` x-sorted distinct-x; `slots` a power of two >= points.len().
pub fn run_pipeline(points: &[Point], slots: usize) -> Result<PramRun, PramError> {
    run_pipeline_with(points, slots, true)
}

/// Like [`run_pipeline`], with CREW strictness configurable.  Non-strict
/// mode counts conflicts instead of failing (last write wins) — useful for
/// cost measurements on data that is not in general position, where tangent
/// ties make the winning pair ambiguous but the counters stay meaningful.
pub fn run_pipeline_with(
    points: &[Point],
    slots: usize,
    strict: bool,
) -> Result<PramRun, PramError> {
    run_pipeline_mode(points, slots, ExecMode::Audited, strict)
}

/// Like [`run_pipeline`], with the execution tier explicit.  `Audited`
/// runs the full CREW + bank-model instrument; `Fast` runs the parallel
/// production engine (no auditing — `strict` is then irrelevant, and the
/// per-stage access counters are zero).  Both tiers produce bit-identical
/// hoods on any CREW-clean input.
pub fn run_pipeline_mode(
    points: &[Point],
    slots: usize,
    mode: ExecMode,
    strict: bool,
) -> Result<PramRun, PramError> {
    run_pipeline_mode_threads(points, slots, mode, strict, 0)
}

/// Like [`run_pipeline_mode`], with the fast tier's per-step PE fan-out
/// capped at `fast_threads` (0 = the machine default, one per hardware
/// thread).  Serving worker pools pass their per-worker thread share so
/// N pooled machines never book N × hardware-width threads at once; the
/// hood is bit-identical at any cap (per-worker write buffers merge in
/// PE order).
pub fn run_pipeline_mode_threads(
    points: &[Point],
    slots: usize,
    mode: ExecMode,
    strict: bool,
    fast_threads: usize,
) -> Result<PramRun, PramError> {
    assert!(slots.is_power_of_two() && slots >= 2);
    assert!(points.len() <= slots);
    let n = slots;
    let lay = Layout { n };
    let mut m = Pram::with_mode(5 * n, n / 2, 1, mode);
    m.strict = strict;
    if fast_threads > 0 {
        m.set_fast_threads(fast_threads);
    }

    // load input hood (host -> device copy; not cost-accounted, matching
    // the paper's cudaMemcpy outside the kernel)
    for (s, p) in points.iter().enumerate() {
        m.mem[lay.hood(s)] = p.x;
        m.mem[lay.hood(s) + 1] = p.y;
    }
    for s in points.len()..n {
        m.mem[lay.hood(s)] = REMOTE.x;
        m.mem[lay.hood(s) + 1] = REMOTE.y;
    }

    let mut per_stage = Vec::new();
    let mut d = 2usize;
    while d < n {
        let before = m.counters.clone();
        run_stage(&mut m, &lay, n, d)?;
        // device newhood -> hood (host-mediated copy in the paper;
        // not cost-accounted, so a flat memmove is fair game)
        m.mem.copy_within(2 * n..4 * n, 0);
        let (d1, d2) = stage_dims(d);
        let c = &m.counters;
        per_stage.push(StageStats {
            d,
            d1,
            d2,
            blocks: n / (2 * d),
            pes: n / 2,
            steps: c.steps - before.steps,
            work: c.work - before.work,
            reads: c.reads - before.reads,
            writes: c.writes - before.writes,
            modeled_cycles: c.modeled_cycles - before.modeled_cycles,
            ideal_cycles: c.ideal_cycles - before.ideal_cycles,
        });
        d *= 2;
    }

    let hood = (0..n)
        .map(|s| Point::new(m.mem[lay.hood(s)], m.mem[lay.hood(s) + 1]))
        .collect();
    Ok(PramRun {
        hood,
        counters: m.counters.clone(),
        per_stage,
    })
}

/// One kernel launch: all blocks, all phases, with barrier steps.
fn run_stage(m: &mut Pram, lay: &Layout, n: usize, d: usize) -> Result<(), PramError> {
    let (d1, d2) = stage_dims(d);
    let pes = n / 2;

    // decompose a PE id exactly like the paper's block/thread indices
    let geom = move |pe: usize| {
        let block = pe / d;
        let indx = pe % d;
        let x = indx % d1;
        let y = indx / d1;
        let start = block * 2 * d;
        (start, indx, x, y)
    };

    // Q-half emptiness test used as the idle guard (broadcast read).
    let q_alive =
        |ctx: &mut PeCtx<'_>, lay: &Layout, start: usize| rd_hood(ctx, lay, start + d).is_live();

    // ---- mam0: scratch init
    m.step(pes, |pe, ctx| {
        let (start, indx, _, _) = geom(pe);
        ctx.write(lay.scratch(start + indx), -1.0);
        ctx.write(lay.scratch(start + indx + d), -1.0);
    })?;

    // ---- mam1: bracket tangent on H(Q) between samples of stride d1
    m.step(pes, |pe, ctx| {
        let (start, _, x, y) = geom(pe);
        if !q_alive(ctx, lay, start) {
            return;
        }
        let i = start + d2 * x;
        if rd_hood(ctx, lay, i).is_remote() {
            return;
        }
        let j = start + d + d1 * y;
        if g_dev(ctx, lay, start, d, i, j) <= Code::Equal
            && (y == d2 - 1 || g_dev(ctx, lay, start, d, i, j + d1) == Code::High)
        {
            ctx.write(lay.scratch(start + x), j as f64);
        }
    })?;

    // ---- mam2: refine to the unique EQUAL within the d1-bracket
    m.step(pes, |pe, ctx| {
        let (start, _, x, y) = geom(pe);
        if !q_alive(ctx, lay, start) {
            return;
        }
        let i = start + d2 * x;
        if rd_hood(ctx, lay, i).is_remote() {
            return;
        }
        let base = ctx.read(lay.scratch(start + x)) as usize;
        let j = base + y;
        if g_dev(ctx, lay, start, d, i, j) == Code::Equal {
            ctx.write(lay.scratch(start + d + x), j as f64);
        } else if d2 < d1 && g_dev(ctx, lay, start, d, i, j + d2) == Code::Equal {
            ctx.write(lay.scratch(start + d + x), (j + d2) as f64);
        }
    })?;

    // ---- mam3: k0 = max P sample with f <= EQUAL  (y == 0 guard: CREW)
    m.step(pes, |pe, ctx| {
        let (start, _, x, y) = geom(pe);
        if y != 0 || !q_alive(ctx, lay, start) {
            return;
        }
        let i = start + d2 * x;
        if rd_hood(ctx, lay, i).is_remote() {
            return;
        }
        let j = ctx.read(lay.scratch(start + d + x)) as usize;
        if f_dev(ctx, lay, start, d, i, j) > Code::Equal {
            return;
        }
        let last = x == d1 - 1 || rd_hood(ctx, lay, i + d2).is_remote();
        let next_high = last || {
            let jn = ctx.read(lay.scratch(start + d + x + 1)) as usize;
            f_dev(ctx, lay, start, d, i + d2, jn) == Code::High
        };
        if next_high {
            ctx.write(lay.scratch(start), i as f64);
        }
    })?;

    // ---- mam4: re-bracket on H(Q) with stride d2 for each exact candidate
    m.step(pes, |pe, ctx| {
        let (start, _, x, y) = geom(pe);
        if !q_alive(ctx, lay, start) {
            return;
        }
        let k0 = ctx.read(lay.scratch(start)) as usize;
        let i = k0 + y;
        ctx.set_reg(0, i as f64); // register: carried into mam5 (CUDA-style)
        if rd_hood(ctx, lay, i).is_remote() {
            return;
        }
        let j = start + d + x * d2;
        if g_dev(ctx, lay, start, d, i, j) <= Code::Equal
            && (x == d1 - 1 || g_dev(ctx, lay, start, d, i, j + d2) == Code::High)
        {
            ctx.write(lay.scratch(start + d + y), j as f64);
        }
    })?;

    // ---- mam5: the unique g == f == EQUAL pair is the tangent
    m.step(pes, |pe, ctx| {
        let (start, _, x, y) = geom(pe);
        if x >= d2 || !q_alive(ctx, lay, start) {
            return;
        }
        let i = ctx.reg(0) as usize;
        if rd_hood(ctx, lay, i).is_remote() {
            return;
        }
        let base = ctx.read(lay.scratch(start + d + y)) as usize;
        let j = base + x;
        if g_dev(ctx, lay, start, d, i, j) == Code::Equal
            && f_dev(ctx, lay, start, d, i, j) == Code::Equal
        {
            ctx.write(lay.scratch(start), i as f64);
            ctx.write(lay.scratch(start + 1), j as f64);
        }
    })?;

    // ---- mam6a: lower half copy-or-REMOTE (bug-fixed), upper half REMOTE
    m.step(pes, |pe, ctx| {
        let (start, indx, _, _) = geom(pe);
        if !q_alive(ctx, lay, start) {
            // Q empty: merged hood is H(P) verbatim (upper half is REMOTE)
            let p = rd_hood(ctx, lay, start + indx);
            ctx.write_pair(lay.newhood(start + indx), p.x, p.y);
            let q = rd_hood(ctx, lay, start + d + indx);
            ctx.write_pair(lay.newhood(start + d + indx), q.x, q.y);
            return;
        }
        let pindex = ctx.read(lay.scratch(start)) as usize;
        let p = rd_hood(ctx, lay, start + indx);
        if start + indx <= pindex {
            ctx.write_pair(lay.newhood(start + indx), p.x, p.y);
        } else {
            ctx.write_pair(lay.newhood(start + indx), REMOTE.x, REMOTE.y);
        }
        ctx.write_pair(lay.newhood(start + d + indx), REMOTE.x, REMOTE.y);
    })?;

    // ---- mam6b: shifted copy of hood[qindex..] to newhood[pindex+1..]
    m.step(pes, |pe, ctx| {
        let (start, indx, _, _) = geom(pe);
        if !q_alive(ctx, lay, start) {
            return;
        }
        let pindex = ctx.read(lay.scratch(start)) as usize;
        let qindex = ctx.read(lay.scratch(start + 1)) as usize;
        let shift = qindex - pindex - 1;
        let src = start + d + indx;
        if src >= qindex {
            let p = rd_hood(ctx, lay, src);
            ctx.write_pair(lay.newhood(src - shift), p.x, p.y);
        }
    })?;

    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::geometry::point::live_prefix;
    use crate::serial::monotone_chain;

    #[test]
    fn pram_matches_serial_all_distributions() {
        for dist in Distribution::ALL {
            for &n in &[8usize, 32, 128] {
                let pts = generate(dist, n, 13);
                let run = run_pipeline(&pts, n).unwrap();
                assert_eq!(
                    live_prefix(&run.hood),
                    &monotone_chain::upper_hull(&pts)[..],
                    "{} n={n}",
                    dist.name()
                );
            }
        }
    }

    #[test]
    fn pram_is_crew_clean() {
        // strict mode would have errored; double-check the counter too
        let pts = generate(Distribution::Circle, 256, 3);
        let run = run_pipeline(&pts, 256).unwrap();
        assert_eq!(run.counters.write_conflicts, 0);
    }

    #[test]
    fn padded_input() {
        let pts = generate(Distribution::UniformSquare, 19, 5);
        let run = run_pipeline(&pts, 32).unwrap();
        assert_eq!(
            live_prefix(&run.hood),
            &monotone_chain::upper_hull(&pts)[..]
        );
    }

    #[test]
    fn time_is_logarithmic_work_is_nlogn() {
        // 8 steps per stage, log2(n)-1 stages
        let pts = generate(Distribution::Disk, 256, 9);
        let run = run_pipeline(&pts, 256).unwrap();
        let stages = 256usize.trailing_zeros() as u64 - 1;
        assert_eq!(run.counters.steps, 8 * stages);
        assert_eq!(run.counters.work, stages * 8 * 128);
        assert_eq!(run.per_stage.len(), stages as usize);
        for st in &run.per_stage {
            assert_eq!(st.steps, 8);
            assert_eq!(st.pes, 128);
            assert_eq!(st.d1 * st.d2, st.d);
        }
    }

    #[test]
    fn fast_tier_matches_audited_bit_for_bit() {
        for dist in Distribution::ALL {
            for &(m, slots) in &[(8usize, 8usize), (100, 128), (256, 256)] {
                let pts = generate(dist, m, 21);
                let a = run_pipeline_mode(&pts, slots, ExecMode::Audited, true).unwrap();
                let f = run_pipeline_mode(&pts, slots, ExecMode::Fast, true).unwrap();
                assert_eq!(a.hood, f.hood, "{} m={m}", dist.name());
                assert_eq!(a.counters.steps, f.counters.steps);
                assert_eq!(a.counters.work, f.counters.work);
                assert_eq!(a.per_stage.len(), f.per_stage.len());
            }
        }
    }

    #[test]
    fn fast_tier_skips_auditing() {
        let pts = generate(Distribution::Disk, 128, 2);
        let run = run_pipeline_mode(&pts, 128, ExecMode::Fast, true).unwrap();
        assert_eq!(run.counters.reads, 0);
        assert_eq!(run.counters.write_conflicts, 0);
        // modeled == ideal == steps: the fast tier is charged as
        // conflict-free
        assert_eq!(run.counters.modeled_cycles, run.counters.steps);
        assert!((run.counters.conflict_factor() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bank_conflicts_present() {
        // the paper's observation: the memory-access pattern conflicts
        let pts = generate(Distribution::Parabola, 512, 4);
        let run = run_pipeline(&pts, 512).unwrap();
        assert!(
            run.counters.conflict_factor() > 1.5,
            "expected serialization, factor {}",
            run.counters.conflict_factor()
        );
    }
}
