"""AOT export path: HLO text shape, manifest integrity, op report."""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

from .test_kernel import make_hood, sorted_points


def test_to_hlo_text_smoke():
    spec = jax.ShapeDtypeStruct((8, 2), jnp.float32)
    lowered = jax.jit(lambda p: (model.upper_hood(p),)).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # tuple return: a root tuple over f32[8,2] exists
    assert "f32[8,2]" in text and "tuple(" in text


def test_op_histogram_counts_instructions():
    spec = jax.ShapeDtypeStruct((8, 2), jnp.float32)
    lowered = jax.jit(lambda p: (model.upper_hood(p),)).lower(spec)
    hist = aot.op_histogram(aot.to_hlo_text(lowered))
    assert sum(hist.values()) > 10
    assert "parameter" in hist


def test_export_all_manifest(tmp_path, monkeypatch):
    """Export a reduced artifact set and validate the manifest."""
    monkeypatch.setattr(aot, "HOOD_SIZES", (8,))
    monkeypatch.setattr(aot, "HULL_SIZES", (8,))
    monkeypatch.setattr(aot, "BATCHES", (1, 2))
    manifest = aot.export_all(tmp_path, report=True)
    assert set(manifest) == {"hood_n8", "hull_n8_b1", "hull_n8_b2",
                             "hood_jnp_n256"}
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk == manifest
    for name, meta in manifest.items():
        text = (tmp_path / meta["file"]).read_text()
        assert text.startswith("HloModule"), name
        assert meta["outputs"] in (1, 2)
    assert (tmp_path / "report.json").exists()


def test_exported_function_executes_like_oracle():
    """Compile the lowered computation back on the local CPU client and
    compare against the oracle — the same check rust does end-to-end."""
    n = 16
    rng = np.random.default_rng(2)
    hood0 = make_hood(sorted_points(rng, 10), n)
    fn = jax.jit(lambda p: (model.upper_hood(p),))
    out = np.asarray(fn(jnp.asarray(hood0))[0])
    np.testing.assert_array_equal(out, ref.ref_hood(hood0))
