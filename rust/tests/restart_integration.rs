//! Durable sessions end-to-end: crash-restart round trips, epoch
//! time-travel over real sockets, corrupt-snapshot behaviour, placement
//! parity and live rebalance.
//!
//! Like the server suite, engines here take their shard count from
//! `ENGINE_SHARDS` (default 1) and their routing policy from
//! `ENGINE_PLACEMENT` (default stripe); tier1 re-runs the whole file
//! with `ENGINE_SHARDS=4 ENGINE_PLACEMENT=ring`, so every property below
//! must hold for any topology — durability is not allowed to depend on
//! where a session happens to live.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use wagener_hull::coordinator::{BackendKind, CoordinatorConfig};
use wagener_hull::engine::{Engine, EngineConfig, PlacementKind};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::Point;
use wagener_hull::serial::monotone_chain;
use wagener_hull::server::{serve_engine, HullClient, ServerConfig, WireProto};
use wagener_hull::store::{self, FsStore, MemStore, SnapshotStore};
use wagener_hull::stream::StreamConfig;
use wagener_hull::util::rng::Rng;

/// Self-cleaning scratch directory for the FsStore tests (the fs module's
/// own helper is crate-private).
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "wagener-restart-{tag}-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&path).unwrap();
        TempDir(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn engine_with(
    store: Option<Arc<dyn SnapshotStore>>,
    merge_threshold: usize,
) -> Arc<Engine> {
    engine_custom(store, merge_threshold, EngineConfig::shards_from_env(1), None)
}

fn engine_custom(
    store: Option<Arc<dyn SnapshotStore>>,
    merge_threshold: usize,
    shards: usize,
    placement: Option<PlacementKind>,
) -> Arc<Engine> {
    Arc::new(
        Engine::start(EngineConfig {
            shards,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Native,
                workers: 1,
                ..Default::default()
            },
            stream: StreamConfig { merge_threshold, idle_ttl_ms: 0, ..Default::default() },
            placement: placement.unwrap_or_else(|| PlacementKind::from_env(PlacementKind::Stripe)),
            store,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn loopback() -> ServerConfig {
    ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() }
}

fn oracle(pts: &[Point]) -> (Vec<Point>, Vec<Point>) {
    monotone_chain::full_hull(pts)
}

/// Crash-restart twins: for every generator distribution, feed a session
/// through a random batch schedule, kill the engine at a random point
/// (the shutdown checkpoint is the "last durable state"), restore into a
/// fresh engine over the same store, finish the schedule, and demand the
/// final hull be bit-identical to an uninterrupted twin AND the serial
/// oracle — with the `inserted == absorbed + pending + hull_points`
/// ledger exact in the final snapshot.
#[test]
fn crash_restart_twin_is_bit_identical_across_all_distributions() {
    let mut rng = Rng::new(0xD0_5EED);
    for (k, dist) in Distribution::ALL.iter().enumerate() {
        let n = 300 + 60 * k;
        let pts = generate(*dist, n, 1000 + k as u64);
        let threshold = rng.range_usize(16, 128);

        // random batch boundaries, random kill point between batches
        let mut batches: Vec<&[Point]> = Vec::new();
        let mut rest = &pts[..];
        while !rest.is_empty() {
            let take = rng.range_usize(1, rest.len().min(120) + 1);
            batches.push(&rest[..take]);
            rest = &rest[take..];
        }
        let kill_at = rng.range_usize(1, batches.len() + 1);

        let store: Arc<MemStore> = Arc::new(MemStore::new());
        let sid = {
            let e = engine_with(Some(store.clone()), threshold);
            let sid = e.session_open().unwrap();
            for b in &batches[..kill_at] {
                e.session_add(sid, b).unwrap();
            }
            sid
            // engine dropped here = crash/restart boundary (checkpoint)
        };

        // restored continuation over the same store
        let e = engine_with(Some(store.clone()), threshold);
        assert_eq!(e.session_restore(sid).unwrap(), sid, "{}", dist.name());
        for b in &batches[kill_at..] {
            e.session_add(sid, b).unwrap();
        }
        let restored = e.session_hull(sid).unwrap();

        // uninterrupted twin fed the identical schedule
        let twin_engine = engine_with(None, threshold);
        let twin_sid = twin_engine.session_open().unwrap();
        for b in &batches {
            twin_engine.session_add(twin_sid, b).unwrap();
        }
        let twin = twin_engine.session_hull(twin_sid).unwrap();

        assert_eq!(restored.epoch, twin.epoch, "{}: epoch diverged", dist.name());
        assert_eq!(restored.upper, twin.upper, "{}: upper diverged", dist.name());
        assert_eq!(restored.lower, twin.lower, "{}: lower diverged", dist.name());
        let (u, l) = oracle(&pts);
        assert_eq!(restored.upper, u, "{}: upper vs oracle", dist.name());
        assert_eq!(restored.lower, l, "{}: lower vs oracle", dist.name());

        // the close-time checkpoint carries the exact accounting ledger
        e.session_close(sid).unwrap();
        let state = store::read_snapshot(&*store, sid).unwrap().unwrap();
        assert_eq!(state.inserted as usize, n, "{}: inserted", dist.name());
        assert!(state.pending.is_empty(), "{}: close flushes", dist.name());
        let mut verts: Vec<Point> =
            state.upper.iter().chain(state.lower.iter()).copied().collect();
        wagener_hull::geometry::point::sort_by_x(&mut verts);
        verts.dedup();
        assert_eq!(
            state.inserted,
            state.absorbed + verts.len() as u64,
            "{}: inserted == absorbed + pending + hull_points",
            dist.name()
        );
    }
}

/// `SHULL <sid> <epoch>` over real sockets: every epoch recorded while
/// the session was live must read back bit-identically later, on BOTH
/// wire protocols, without perturbing the live session; epoch 0 is the
/// empty hull and a future epoch is the typed `unknown-epoch`.
#[test]
fn shull_serves_every_recorded_epoch_over_the_wire() {
    let engine = engine_with(None, 48);
    let handle = serve_engine(engine, &loopback()).unwrap();
    let mut text = HullClient::connect_with(handle.local_addr, WireProto::Text).unwrap();
    let mut bin = HullClient::connect_with(handle.local_addr, WireProto::Binary).unwrap();

    let pts = generate(Distribution::Circle, 600, 77);
    let sid = text.session_open().unwrap();
    // record the historical hull the moment each epoch first exists
    let mut recorded = vec![text.session_hull_at(sid, 0).unwrap()];
    for chunk in pts.chunks(37) {
        let ack = text.session_add(sid, chunk).unwrap();
        while (recorded.len() as u64) <= ack.epoch {
            let e = recorded.len() as u64;
            recorded.push(text.session_hull_at(sid, e).unwrap());
        }
    }
    let live = text.session_hull(sid).unwrap(); // flush = final epoch
    while (recorded.len() as u64) <= live.epoch {
        let e = recorded.len() as u64;
        recorded.push(text.session_hull_at(sid, e).unwrap());
    }
    assert!(live.epoch >= 2, "schedule must produce several epochs");

    // epoch 0: the empty hull every session starts from
    assert!(recorded[0].upper.is_empty() && recorded[0].lower.is_empty());
    // the final epoch's historical read is the live hull
    assert_eq!(recorded[live.epoch as usize].upper, live.upper);
    assert_eq!(recorded[live.epoch as usize].lower, live.lower);
    let (u, l) = oracle(&pts);
    assert_eq!(live.upper, u);
    assert_eq!(live.lower, l);

    // time travel is immutable: every epoch re-reads bit-identically on
    // both protocols, long after the session moved on
    for (e, want) in recorded.iter().enumerate() {
        for c in [&mut text, &mut bin] {
            let got = c.session_hull_at(sid, e as u64).unwrap();
            assert_eq!(got.epoch, e as u64);
            assert_eq!(got.upper, want.upper, "epoch {e} upper changed");
            assert_eq!(got.lower, want.lower, "epoch {e} lower changed");
        }
    }

    // a future epoch is a typed error on both protocols; the historical
    // reads above must not have flushed anything (same epoch still)
    for c in [&mut text, &mut bin] {
        let err = c.session_hull_at(sid, live.epoch + 1).unwrap_err();
        assert!(err.to_string().contains("unknown-epoch"), "{err}");
    }
    assert_eq!(text.session_hull(sid).unwrap().epoch, live.epoch);
    text.session_close(sid).unwrap();
    handle.stop();
}

/// A server backed by an FsStore: SCLOSE writes the final checkpoint,
/// `SOPEN <id> <sid>` restores it bit-identically over the wire, and
/// every flavour of on-disk corruption answers a typed
/// `snapshot-corrupt` error — never a panic, never a wrong hull — while
/// the connection and server stay fully usable.
#[test]
fn corrupt_snapshots_answer_typed_errors_over_the_wire() {
    let dir = TempDir::new("corrupt");
    let fs: Arc<FsStore> = Arc::new(FsStore::open(&dir.0).unwrap());
    let engine = engine_with(Some(fs.clone()), 32);
    let handle = serve_engine(engine, &loopback()).unwrap();
    let mut c = HullClient::connect(handle.local_addr).unwrap();

    let pts = generate(Distribution::Valley, 400, 9);
    let sid = c.session_open().unwrap();
    for chunk in pts.chunks(64) {
        c.session_add(sid, chunk).unwrap();
    }
    let before = c.session_hull(sid).unwrap();
    c.session_close(sid).unwrap();

    // clean restore first: bit-identical to the pre-close hull
    assert_eq!(c.session_restore(sid).unwrap(), sid);
    let after = c.session_hull(sid).unwrap();
    assert_eq!(after.epoch, before.epoch);
    assert_eq!(after.upper, before.upper);
    assert_eq!(after.lower, before.lower);
    c.session_close(sid).unwrap();

    // corrupt every chunk in turn: flip one byte, restore must answer the
    // typed error; un-flip, and the snapshot is whole again
    let chunk_dir = dir.0.join("chunks");
    let chunks: Vec<PathBuf> = std::fs::read_dir(&chunk_dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| std::fs::metadata(p).map(|m| m.len() > 0).unwrap_or(false))
        .collect();
    assert!(!chunks.is_empty(), "close must have written point chunks");
    for path in &chunks {
        let mut data = std::fs::read(path).unwrap();
        data[0] ^= 0x01;
        std::fs::write(path, &data).unwrap();
        let err = c.session_restore(sid).unwrap_err();
        assert!(err.to_string().contains("snapshot-corrupt"), "{err}");
        data[0] ^= 0x01;
        std::fs::write(path, &data).unwrap();
    }
    // a deleted chunk is corruption too (dangling manifest reference)
    let victim = &chunks[0];
    let saved = std::fs::read(victim).unwrap();
    std::fs::remove_file(victim).unwrap();
    let err = c.session_restore(sid).unwrap_err();
    assert!(err.to_string().contains("snapshot-corrupt"), "{err}");
    std::fs::write(victim, &saved).unwrap();

    // a scribbled manifest is typed as well
    let manifest = dir.0.join("sessions").join(format!("{sid}.json"));
    let good = std::fs::read(&manifest).unwrap();
    std::fs::write(&manifest, b"}{ not json").unwrap();
    let err = c.session_restore(sid).unwrap_err();
    assert!(err.to_string().contains("snapshot-corrupt"), "{err}");
    std::fs::write(&manifest, &good).unwrap();

    // after all that abuse: the server never wavered and the snapshot
    // restores exactly
    c.ping().unwrap();
    assert_eq!(c.session_restore(sid).unwrap(), sid);
    let fin = c.session_hull(sid).unwrap();
    assert_eq!(fin.upper, before.upper);
    assert_eq!(fin.lower, before.lower);
    // restoring a sid that was never snapshotted stays unknown-session
    let err = c.session_restore(987_654).unwrap_err();
    assert!(err.to_string().contains("unknown-session"), "{err}");
    handle.stop();
}

/// FsStore survives a full process-style restart: a second engine built
/// over the same directory restores the session bit-identically and the
/// continued stream converges on the oracle hull.
#[test]
fn fs_store_restart_roundtrip_is_bit_identical() {
    let dir = TempDir::new("roundtrip");
    let pts = generate(Distribution::Clusters(5), 500, 3);
    let (first, second) = pts.split_at(280);
    let (sid, mid) = {
        let fs: Arc<FsStore> = Arc::new(FsStore::open(&dir.0).unwrap());
        let e = engine_with(Some(fs), 40);
        let sid = e.session_open().unwrap();
        e.session_add(sid, first).unwrap();
        let mid = e.session_hull(sid).unwrap();
        (sid, mid)
    };
    // "new process": a fresh FsStore over the same directory
    let fs: Arc<FsStore> = Arc::new(FsStore::open(&dir.0).unwrap());
    let e = engine_with(Some(fs), 40);
    assert_eq!(e.session_restore(sid).unwrap(), sid);
    let back = e.session_hull(sid).unwrap();
    assert_eq!(back.epoch, mid.epoch);
    assert_eq!(back.upper, mid.upper);
    assert_eq!(back.lower, mid.lower);
    // every pre-restart epoch is still servable from the restored ledger
    for epoch in 0..=mid.epoch {
        e.session_hull_at(sid, Some(epoch)).unwrap();
    }
    e.session_add(sid, second).unwrap();
    let fin = e.session_hull(sid).unwrap();
    let (u, l) = oracle(&pts);
    assert_eq!(fin.upper, u);
    assert_eq!(fin.lower, l);
}

/// An idle session the TTL sweeper evicts is checkpointed first, so
/// `SOPEN <id> <sid>` brings it back over the wire — and the STATS
/// frame carries the new durability counters.
#[test]
fn evicted_session_restores_from_its_final_snapshot() {
    let store: Arc<MemStore> = Arc::new(MemStore::new());
    let engine = Arc::new(
        Engine::start(EngineConfig {
            shards: EngineConfig::shards_from_env(1),
            coordinator: CoordinatorConfig {
                backend: BackendKind::Native,
                workers: 1,
                ..Default::default()
            },
            stream: StreamConfig { merge_threshold: 32, idle_ttl_ms: 150, ..Default::default() },
            placement: PlacementKind::from_env(PlacementKind::Stripe),
            store: Some(store),
            ..Default::default()
        })
        .unwrap(),
    );
    let handle = serve_engine(engine, &loopback()).unwrap();
    let mut c = HullClient::connect(handle.local_addr).unwrap();

    let pts = generate(Distribution::Parabola, 200, 21);
    let sid = c.session_open().unwrap();
    for chunk in pts.chunks(50) {
        c.session_add(sid, chunk).unwrap();
    }
    let before = c.session_hull(sid).unwrap();

    std::thread::sleep(std::time::Duration::from_millis(300));
    handle.engine().sweep_now();
    let err = c.session_add(sid, &pts[..1]).unwrap_err();
    assert!(err.to_string().contains("unknown-session"), "{err}");

    // the eviction wrote a final snapshot: the session comes back whole
    assert_eq!(c.session_restore(sid).unwrap(), sid);
    let after = c.session_hull(sid).unwrap();
    assert_eq!(after.epoch, before.epoch);
    assert_eq!(after.upper, before.upper);
    assert_eq!(after.lower, before.lower);
    c.session_close(sid).unwrap();

    let stats = c.stats().unwrap();
    let json = wagener_hull::util::json::parse(&stats).unwrap();
    assert!(
        json.get("snapshots_written_total").unwrap().as_usize().unwrap() >= 1,
        "{stats}"
    );
    assert_eq!(json.get("restores_total").unwrap().as_usize(), Some(1), "{stats}");
    assert!(json.get("snapshot_bytes_total").unwrap().as_usize().unwrap() > 0, "{stats}");
    handle.stop();
}

/// Placement parity: the same session schedule produces identical sids
/// and bit-identical hulls on a 1-shard engine, a 4-shard stripe engine
/// and a 4-shard ring engine — topology must never leak into results.
#[test]
fn stripe_and_ring_serve_identical_sessions_at_any_shard_count() {
    let configs: [(usize, PlacementKind); 3] = [
        (1, PlacementKind::Stripe),
        (4, PlacementKind::Stripe),
        (4, PlacementKind::Ring),
    ];
    let mut outcomes: Vec<Vec<(u64, u64, Vec<Point>, Vec<Point>)>> = Vec::new();
    for (shards, placement) in configs {
        let e = engine_custom(None, 48, shards, Some(placement));
        let mut sids = Vec::new();
        for _ in 0..6 {
            sids.push(e.session_open().unwrap());
        }
        // interleave the six sessions' feeds round-robin
        let feeds: Vec<Vec<Point>> = (0..6)
            .map(|i| generate(Distribution::ALL[i % 7], 180 + 10 * i, 50 + i as u64))
            .collect();
        for step in 0..6 {
            for (i, sid) in sids.iter().enumerate() {
                let chunk_len = feeds[i].len() / 6;
                let lo = step * chunk_len;
                let hi = if step == 5 { feeds[i].len() } else { lo + chunk_len };
                e.session_add(*sid, &feeds[i][lo..hi]).unwrap();
            }
        }
        let mut run = Vec::new();
        for (i, sid) in sids.iter().enumerate() {
            let snap = e.session_hull(*sid).unwrap();
            let (u, l) = oracle(&feeds[i]);
            assert_eq!(snap.upper, u, "shards={shards} {placement:?} sid {sid}");
            assert_eq!(snap.lower, l, "shards={shards} {placement:?} sid {sid}");
            run.push((*sid, snap.epoch, snap.upper, snap.lower));
            e.session_close(*sid).unwrap();
        }
        outcomes.push(run);
    }
    assert_eq!(outcomes[0], outcomes[1], "stripe 4-shard diverged from 1-shard");
    assert_eq!(outcomes[0], outcomes[2], "ring 4-shard diverged from 1-shard");
}

/// Rebalancing a live session between shards mid-schedule changes no
/// observable client outcome: the feed continues over the same
/// connection, the hull matches the oracle, and historical epochs read
/// the same before and after the move.
#[test]
fn rebalance_mid_schedule_is_invisible_over_the_wire() {
    let engine = engine_custom(None, 48, 4, Some(PlacementKind::Stripe));
    let handle = serve_engine(engine.clone(), &loopback()).unwrap();
    let mut c = HullClient::connect(handle.local_addr).unwrap();

    let pts = generate(Distribution::Disk, 600, 11);
    let sid = c.session_open().unwrap();
    let (first, rest) = pts.split_at(300);
    for chunk in first.chunks(60) {
        c.session_add(sid, chunk).unwrap();
    }
    let pre = c.session_hull(sid).unwrap();
    let history: Vec<_> =
        (0..=pre.epoch).map(|e| c.session_hull_at(sid, e).unwrap()).collect();

    // bounce the session across every other shard and back
    let home = engine.shard_of(sid);
    for hop in 1..4 {
        engine.rebalance(sid, (home + hop) % 4).unwrap();
        c.session_add(sid, &rest[(hop - 1) * 100..hop * 100]).unwrap();
    }
    engine.rebalance(sid, home).unwrap();

    let fin = c.session_hull(sid).unwrap();
    let (u, l) = oracle(&pts);
    assert_eq!(fin.upper, u);
    assert_eq!(fin.lower, l);
    // history moved with the session, bit-identically
    for (e, want) in history.iter().enumerate() {
        let got = c.session_hull_at(sid, e as u64).unwrap();
        assert_eq!(got.upper, want.upper, "epoch {e} changed across rebalance");
        assert_eq!(got.lower, want.lower, "epoch {e} changed across rebalance");
    }
    c.session_close(sid).unwrap();
    handle.stop();
}
