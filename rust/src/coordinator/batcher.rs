//! Dynamic batcher: size-class queues with batch-full / deadline flushing.
//!
//! Requests of similar size are grouped (padding waste is bounded by the
//! power-of-two class) and flushed to the execution thread when a class
//! reaches the batch limit or its oldest request exceeds the flush
//! deadline — the standard continuous-batching trade-off between
//! throughput (bigger batches amortize dispatch) and p99 latency.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::request::{HullReply, Prepared, RequestError};

/// Batching policy knobs (config file: `[batcher]`).
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// flush a class at this many requests (0 = backend's preference).
    pub max_batch: usize,
    /// flush a class when its oldest request is older than this.
    pub flush_us: u64,
    /// submission queue capacity (backpressure bound).
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 0, flush_us: 500, queue_cap: 1024 }
    }
}

/// A queued request with its reply destination.
pub(crate) struct Item {
    pub prepared: Prepared,
    pub enqueued: Instant,
    pub reply: HullReply,
}

/// A flushed batch (all items share a size class).  An EMPTY batch is the
/// batcher's shutdown pill: it sends one per exec worker after draining,
/// and a worker that dequeues one exits (workers hold a retry sender
/// clone, so the channel alone can never disconnect — see `run_exec_worker`).
pub(crate) struct BatchMsg {
    pub items: Vec<Item>,
    /// dispatch attempt: 0 = first, 1 = the one bounded retry after a
    /// backend failure (re-enqueued so a different worker picks it up).
    pub attempt: u8,
}

/// Answer one deadline-expired item (`errors` + `deadline_exceeded`; the
/// request was admitted, so the error keeps `in_flight` balanced).
pub(crate) fn expire_item(item: Item, metrics: &Metrics) {
    Metrics::inc(&metrics.errors);
    Metrics::inc(&metrics.deadline_exceeded);
    metrics.queue_latency.record(item.enqueued.elapsed());
    item.reply.send(Err(RequestError::DeadlineExceeded));
}

/// Drop every already-expired item from a batch, answering each with
/// `deadline-exceeded`.  Shared by the batcher (dequeue/flush) and the
/// exec workers (pre-dispatch check).
pub(crate) fn reap_expired(items: &mut Vec<Item>, metrics: &Metrics) {
    let now = Instant::now();
    if items.iter().any(|i| i.prepared.expired(now)) {
        let mut kept = Vec::with_capacity(items.len());
        for item in items.drain(..) {
            if item.prepared.expired(now) {
                expire_item(item, metrics);
            } else {
                kept.push(item);
            }
        }
        *items = kept;
    }
}

/// Size-class key: smallest power of two >= the request's point count
/// (min 2, the smallest hood).
pub fn size_class(m: usize) -> usize {
    m.max(2).next_power_of_two()
}

/// The batcher loop: runs on its own thread until the submit side closes.
/// On its way out it sends one empty pill per exec worker so the pool can
/// drain deterministically even though workers hold retry sender clones.
pub(crate) fn run_batcher(
    rx: mpsc::Receiver<Item>,
    tx: mpsc::SyncSender<BatchMsg>,
    max_batch: usize,
    flush_us: u64,
    workers: usize,
    metrics: Arc<Metrics>,
) {
    let flush = Duration::from_micros(flush_us.max(1));
    let mut queues: BTreeMap<usize, Vec<Item>> = BTreeMap::new();

    let flush_class = |mut items: Vec<Item>, tx: &mpsc::SyncSender<BatchMsg>| {
        // request deadlines are enforced at dequeue: an expired item is
        // answered here instead of occupying a worker slot
        reap_expired(&mut items, &metrics);
        if !items.is_empty() {
            // receiver gone => shutting down; drop items (their reply
            // channels die, submitters observe Shutdown)
            let _ = tx.send(BatchMsg { items, attempt: 0 });
        }
    };

    loop {
        // earliest deadline across queues bounds the wait
        let now = Instant::now();
        let next_deadline = queues
            .values()
            .filter_map(|q| q.first())
            .map(|i| i.enqueued + flush)
            .min();
        let wait = match next_deadline {
            Some(dl) => dl.saturating_duration_since(now).min(flush),
            None => flush,
        };
        match rx.recv_timeout(wait) {
            Ok(item) => {
                if item.prepared.expired(Instant::now()) {
                    // expired while waiting in the submit queue: answer
                    // now, never enqueue (the sweep below still runs)
                    expire_item(item, &metrics);
                } else {
                    let class = size_class(item.prepared.points.len());
                    let q = queues.entry(class).or_default();
                    q.push(item);
                    if q.len() >= max_batch {
                        let items = std::mem::take(q);
                        flush_class(items, &tx);
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for (_, q) in std::mem::take(&mut queues) {
                    flush_class(q, &tx);
                }
                // one pill per worker: each consumes exactly one and exits
                for _ in 0..workers {
                    if tx.send(BatchMsg { items: Vec::new(), attempt: 0 }).is_err() {
                        break;
                    }
                }
                return;
            }
        }
        // deadline sweep
        let now = Instant::now();
        let expired: Vec<usize> = queues
            .iter()
            .filter(|(_, q)| q.first().is_some_and(|i| now >= i.enqueued + flush))
            .map(|(&c, _)| c)
            .collect();
        for c in expired {
            let items = queues.remove(&c).unwrap_or_default();
            flush_class(items, &tx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{HullResponse, RequestError};
    use crate::geometry::point::Point;

    fn item(m: usize, reply: mpsc::Sender<Result<HullResponse, RequestError>>) -> Item {
        item_deadline(m, reply, None)
    }

    fn item_deadline(
        m: usize,
        reply: mpsc::Sender<Result<HullResponse, RequestError>>,
        deadline: Option<Instant>,
    ) -> Item {
        Item {
            prepared: Prepared {
                id: m as u64,
                points: (0..m)
                    .map(|i| Point::new(i as f64 / m as f64, 0.5))
                    .collect(),
                degenerate: false,
                filtered: 0,
                deadline,
            },
            enqueued: Instant::now(),
            reply: HullReply::Channel(reply),
        }
    }

    fn spawn_batcher(
        irx: mpsc::Receiver<Item>,
        btx: mpsc::SyncSender<BatchMsg>,
        max_batch: usize,
        flush_us: u64,
    ) -> (std::thread::JoinHandle<()>, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || run_batcher(irx, btx, max_batch, flush_us, 1, m2));
        (h, metrics)
    }

    #[test]
    fn size_classes() {
        assert_eq!(size_class(1), 2);
        assert_eq!(size_class(2), 2);
        assert_eq!(size_class(3), 4);
        assert_eq!(size_class(64), 64);
        assert_eq!(size_class(65), 128);
    }

    #[test]
    fn flushes_when_batch_full() {
        let (itx, irx) = mpsc::channel();
        let (btx, brx) = mpsc::sync_channel(16);
        let (h, _m) = spawn_batcher(irx, btx, 3, 100_000);
        let (rtx, _rrx) = mpsc::channel();
        for _ in 0..3 {
            itx.send(item(10, rtx.clone())).unwrap();
        }
        let batch = brx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(batch.items.len(), 3);
        drop(itx);
        h.join().unwrap();
    }

    #[test]
    fn flushes_on_deadline() {
        let (itx, irx) = mpsc::channel();
        let (btx, brx) = mpsc::sync_channel(16);
        let (h, _m) = spawn_batcher(irx, btx, 100, 2_000);
        let (rtx, _rrx) = mpsc::channel();
        itx.send(item(10, rtx.clone())).unwrap();
        let t0 = Instant::now();
        let batch = brx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(batch.items.len(), 1);
        assert!(t0.elapsed() >= Duration::from_micros(1_500), "{:?}", t0.elapsed());
        drop(itx);
        h.join().unwrap();
    }

    #[test]
    fn separates_size_classes() {
        let (itx, irx) = mpsc::channel();
        let (btx, brx) = mpsc::sync_channel(16);
        let (h, _m) = spawn_batcher(irx, btx, 2, 50_000);
        let (rtx, _rrx) = mpsc::channel();
        itx.send(item(10, rtx.clone())).unwrap(); // class 16
        itx.send(item(100, rtx.clone())).unwrap(); // class 128
        itx.send(item(12, rtx.clone())).unwrap(); // class 16 -> flush
        let batch = brx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(batch.items.len(), 2);
        for it in &batch.items {
            assert_eq!(size_class(it.prepared.points.len()), 16);
        }
        drop(itx);
        // remaining class flushed on disconnect
        let rest = brx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(rest.items.len(), 1);
        h.join().unwrap();
    }

    /// Under sustained load that never fills a batch, the deadline sweep
    /// must keep flushing: no item may wait unboundedly just because new
    /// items keep arriving (the recv-loop services arrivals AND deadlines).
    #[test]
    fn deadline_holds_under_sustained_load() {
        let (itx, irx) = mpsc::channel();
        let (btx, brx) = mpsc::sync_channel(64);
        let flush_us = 3_000u64;
        let (h, _m) = spawn_batcher(irx, btx, 1000, flush_us);
        let (rtx, _rrx) = mpsc::channel();

        let feeder = std::thread::spawn(move || {
            for _ in 0..40 {
                itx.send(item(10, rtx.clone())).unwrap();
                std::thread::sleep(Duration::from_millis(1));
            }
            // itx drops here: batcher drains and exits
        });

        let mut batches = 0usize;
        let mut got = 0usize;
        while got < 40 {
            let batch = brx.recv_timeout(Duration::from_secs(5)).expect("batcher stalled");
            let now = Instant::now();
            for it in &batch.items {
                // generous bound: the point is "not unbounded", and the
                // batches > 3 check below proves deadline flushing fired;
                // a tight wall-clock bound here flakes on loaded CI boxes
                let waited = now.duration_since(it.enqueued);
                assert!(
                    waited < Duration::from_secs(1),
                    "item waited {waited:?} under sustained load"
                );
            }
            got += batch.items.len();
            batches += 1;
        }
        assert!(
            batches > 3,
            "deadline flushes never fired mid-load: {batches} batches for 40 items"
        );
        feeder.join().unwrap();
        h.join().unwrap();
    }

    /// An item whose deadline passed while queued is answered
    /// `deadline-exceeded` at dequeue and never reaches a worker.
    #[test]
    fn expired_items_answered_at_dequeue() {
        let (itx, irx) = mpsc::channel();
        let (btx, brx) = mpsc::sync_channel(16);
        let (h, metrics) = spawn_batcher(irx, btx, 2, 1_000);
        let (rtx, rrx) = mpsc::channel();
        // already expired on arrival
        itx.send(item_deadline(10, rtx.clone(), Some(Instant::now() - Duration::from_millis(1))))
            .unwrap();
        match rrx.recv_timeout(Duration::from_secs(2)).unwrap() {
            Err(RequestError::DeadlineExceeded) => {}
            other => panic!("expected deadline-exceeded, got {other:?}"),
        }
        // live item still flows through normally
        itx.send(item_deadline(10, rtx.clone(), Some(Instant::now() + Duration::from_secs(60))))
            .unwrap();
        let batch = brx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(batch.items.len(), 1);
        assert_eq!(metrics.deadline_exceeded.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(metrics.errors.load(std::sync::atomic::Ordering::Relaxed), 1);
        drop(itx);
        h.join().unwrap();
    }

    /// The drain path ends with one empty pill per worker so the pool can
    /// exit even though workers hold retry sender clones.
    #[test]
    fn drain_emits_one_pill_per_worker() {
        let (itx, irx) = mpsc::channel();
        let (btx, brx) = mpsc::sync_channel(16);
        let metrics = Arc::new(Metrics::default());
        let h = std::thread::spawn(move || run_batcher(irx, btx, 100, 1_000, 3, metrics));
        let (rtx, _rrx) = mpsc::channel();
        itx.send(item(5, rtx.clone())).unwrap();
        drop(itx);
        let batch = brx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(batch.items.len(), 1);
        assert_eq!(batch.attempt, 0);
        for _ in 0..3 {
            let pill = brx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert!(pill.items.is_empty(), "pill carried items");
        }
        assert!(brx.recv_timeout(Duration::from_millis(100)).is_err());
        h.join().unwrap();
    }

    #[test]
    fn drains_on_disconnect() {
        let (itx, irx) = mpsc::channel();
        let (btx, brx) = mpsc::sync_channel(16);
        let (h, _m) = spawn_batcher(irx, btx, 100, 1_000_000);
        let (rtx, _rrx) = mpsc::channel();
        itx.send(item(5, rtx.clone())).unwrap();
        drop(itx);
        let batch = brx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(batch.items.len(), 1);
        h.join().unwrap();
    }
}
