//! Streaming hull sessions: incremental maintenance over long-lived
//! connections.
//!
//! The one-shot pipeline re-hulls every request from scratch and forgets
//! the answer; under update-heavy traffic almost all of that work is
//! redundant.  This subsystem keeps per-client state: a [`Session`] holds
//! the current hull, interior-rejects inserts in O(log h) with exact
//! predicates (the GPU-filter literature's cheap-rejection trick, applied
//! against the *true* hull instead of an octagon), buffers the survivors,
//! and periodically folds them back in — the pending set goes through the
//! ordinary coordinator backends and the resulting hull⊕hull pair through
//! the paper's common-tangent merge ([`crate::wagener::hull_merge`]).
//!
//! The [`SessionRegistry`] owns the fleet: session tokens, a capacity
//! cap, idle-TTL eviction (sweeps take the per-session lock, so eviction
//! can never race an in-flight `SADD`), and the serving metrics
//! (open-session gauge, absorbed/pending counters, merge latency).
//! Wire verbs: `SOPEN` / `SADD` / `SHULL` / `SCLOSE` (see
//! [`crate::server::proto`]).

pub mod registry;
pub mod session;

pub use registry::{SessionError, SessionHullSnapshot, SessionRegistry, StreamConfig};
pub use session::{AddOutcome, HullService, Session};
