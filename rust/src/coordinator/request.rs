//! Request/response types and input preprocessing.

use std::time::Instant;

use crate::geometry::point::{sort_by_x, Point};
use crate::geometry::predicates::{orient2d, Orientation};

/// A hull computation request (raw client points, any order).
#[derive(Clone, Debug)]
pub struct HullRequest {
    pub id: u64,
    pub points: Vec<Point>,
    /// Absolute completion deadline.  A request past it answers
    /// `deadline-exceeded` instead of occupying a worker; `None` waits
    /// forever (the pre-deadline behaviour).
    pub deadline: Option<Instant>,
}

impl HullRequest {
    pub fn new(id: u64, points: Vec<Point>) -> HullRequest {
        HullRequest { id, points, deadline: None }
    }

    /// Attach an absolute deadline (builder-style).
    pub fn with_deadline(mut self, deadline: Option<Instant>) -> HullRequest {
        self.deadline = deadline;
        self
    }
}

/// A completed hull: upper and lower chains, left-to-right, plus timings.
#[derive(Clone, Debug)]
pub struct HullResponse {
    pub id: u64,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
    /// which backend computed it ("pjrt", "native", "serial", ...).
    pub backend: &'static str,
    pub queue_ns: u64,
    pub exec_ns: u64,
}

/// Where a finished hull computation gets delivered.
///
/// `Channel` is the classic blocking path: the submitter parks on the
/// receiver.  `Sink` is the non-blocking path for the event-loop server:
/// the closure runs on whichever thread completes the request (the
/// caller's for early rejections, an exec worker's otherwise), so ten
/// thousand in-flight requests cost zero parked threads.
pub enum HullReply {
    Channel(std::sync::mpsc::Sender<Result<HullResponse, RequestError>>),
    Sink(SinkReply),
}

impl HullReply {
    /// Wrap a completion callback as a reply destination.
    pub fn sink(f: impl FnOnce(Result<HullResponse, RequestError>) + Send + 'static) -> HullReply {
        HullReply::Sink(SinkReply(Some(Box::new(f))))
    }

    /// Deliver the result, consuming the reply.  A hung-up channel
    /// receiver is ignored, matching the old `let _ = tx.send(..)` sites.
    pub fn send(self, result: Result<HullResponse, RequestError>) {
        match self {
            HullReply::Channel(tx) => {
                let _ = tx.send(result);
            }
            HullReply::Sink(s) => s.call(result),
        }
    }
}

/// A callback reply that can never be lost: if the holder drops it
/// without answering (e.g. the batcher discards queued items during
/// shutdown), the callback still fires with `Shutdown` — the sink
/// analogue of a dropped channel sender disconnecting its receiver.
pub struct SinkReply(Option<Box<dyn FnOnce(Result<HullResponse, RequestError>) + Send>>);

impl SinkReply {
    fn call(mut self, result: Result<HullResponse, RequestError>) {
        if let Some(f) = self.0.take() {
            f(result);
        }
    }
}

impl Drop for SinkReply {
    fn drop(&mut self) {
        if let Some(f) = self.0.take() {
            f(Err(RequestError::Shutdown));
        }
    }
}

/// Input rejection reasons.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestError {
    Empty,
    NonFinite(usize),
    OutOfRange(usize),
    TooLarge { points: usize, max: usize },
    Backend(String),
    Shutdown,
    /// The request's deadline passed before a worker could answer it
    /// (admission, batcher dequeue, or pre-dispatch check).
    DeadlineExceeded,
    /// Load shedding: every candidate shard was at its
    /// `[engine] max_queued` ceiling when the request arrived.
    Overloaded,
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Empty => write!(f, "empty point set"),
            RequestError::NonFinite(i) => write!(f, "point {i} is not finite"),
            RequestError::OutOfRange(i) => {
                write!(f, "point {i} outside [0,1]x[0,1] (normalize first)")
            }
            RequestError::TooLarge { points, max } => {
                write!(f, "{points} points exceeds the largest size class {max}")
            }
            RequestError::Backend(e) => write!(f, "backend failure: {e}"),
            RequestError::Shutdown => write!(f, "coordinator is shutting down"),
            // single tokens: the wire-visible typed errors — one spelling,
            // owned by the shared table in `crate::errors`
            RequestError::DeadlineExceeded => {
                f.write_str(crate::errors::TypedError::DeadlineExceeded.wire_token())
            }
            RequestError::Overloaded => {
                f.write_str(crate::errors::TypedError::Overloaded.wire_token())
            }
        }
    }
}

impl std::error::Error for RequestError {}

/// Preprocessed request ready for a Wagener backend.
#[derive(Clone, Debug)]
pub struct Prepared {
    pub id: u64,
    /// x-sorted, f32-quantized points.
    pub points: Vec<Point>,
    /// general position violated (duplicate x): needs the exact fallback.
    pub degenerate: bool,
    /// points discarded by the octagon interior-point pre-filter.
    pub filtered: usize,
    /// absolute completion deadline carried from the request.
    pub deadline: Option<Instant>,
}

impl Prepared {
    /// True once the deadline has passed (`None` never expires).
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Validate raw client points: finite coordinates inside the paper's
/// [0,1] box.  Shared by `prepare` and the streaming-session insert path
/// so both reject identical inputs with identical indices.
pub fn validate_points(points: &[Point]) -> Result<(), RequestError> {
    for (i, p) in points.iter().enumerate() {
        if !p.x.is_finite() || !p.y.is_finite() {
            return Err(RequestError::NonFinite(i));
        }
        if !(0.0..=1.0).contains(&p.x) || !(0.0..=1.0).contains(&p.y) {
            return Err(RequestError::OutOfRange(i));
        }
    }
    Ok(())
}

/// Below this, the octagon test costs more than the hull it would save.
/// Shared with the device prefilter (`HullBackend::device_filter`), whose
/// kernel bakes in the same gate.
pub(crate) const PREFILTER_MIN_POINTS: usize = 32;

/// Octagon interior-point pre-filter (the CudaChain / GPU-filter trick):
/// points *strictly* inside the convex polygon spanned by the extreme
/// points of the 8 directions ±x, ±y, ±(x+y), ±(x−y) cannot be hull
/// vertices, so large dense inputs shrink before they reach a backend.
///
/// Exact by construction: the test uses the robust orientation predicate
/// and keeps anything on the polygon boundary, so the hull of the kept
/// set is bit-identical to the hull of the input.  Input must be sorted;
/// order is preserved.  Filters in place (no per-point allocation —
/// nothing moves when no point is inside) and returns the number dropped;
/// 0 when filtering is not worthwhile (small input, degenerate octagon).
pub(crate) fn octagon_filter(pts: &mut Vec<Point>) -> usize {
    if pts.len() < PREFILTER_MIN_POINTS {
        return 0;
    }
    // extreme point per direction, counter-clockwise starting at W:
    //   W = min x, SW = min x+y, S = min y, SE = max x−y,
    //   E = max x, NE = max x+y, N = max y, NW = min x−y
    // — all eight maxima from ONE pass over the points (this runs on the
    // submit() hot path for every request ≥ the size floor)
    fn keys(p: &Point) -> [f64; 8] {
        [
            -p.x,
            -(p.x + p.y),
            -p.y,
            p.x - p.y,
            p.x,
            p.x + p.y,
            p.y,
            -(p.x - p.y),
        ]
    }
    let mut best = [pts[0]; 8];
    let mut best_k = keys(&pts[0]);
    for p in &pts[1..] {
        let k = keys(p);
        for dir in 0..8 {
            if k[dir] > best_k[dir] {
                best_k[dir] = k[dir];
                best[dir] = *p;
            }
        }
    }
    let mut octagon: Vec<Point> = Vec::with_capacity(8);
    for b in best {
        if octagon.last() != Some(&b) {
            octagon.push(b);
        }
    }
    while octagon.len() > 1 && octagon.first() == octagon.last() {
        octagon.pop();
    }
    if octagon.len() < 3 {
        return 0; // all extremes (near-)coincident: nothing to gain
    }
    // tie-breaking among equal-key extremes can in principle produce a
    // degenerate traversal; a right turn anywhere voids the convexity
    // proof the filter rests on, so bail out rather than risk dropping a
    // hull vertex (≤ 8 robust predicate calls)
    let m = octagon.len();
    for i in 0..m {
        let (a, b, c) = (octagon[i], octagon[(i + 1) % m], octagon[(i + 2) % m]);
        if orient2d(a, b, c) == Orientation::Right {
            return 0;
        }
    }
    let strictly_inside = |p: &Point| {
        (0..m).all(|i| orient2d(octagon[i], octagon[(i + 1) % m], *p) == Orientation::Left)
    };
    let before = pts.len();
    pts.retain(|p| !strictly_inside(p));
    before - pts.len()
}

/// Validate + canonicalize a request.
///
/// Points are quantized to f32 (the artifact wire type) and x-sorted; the
/// paper's coordinate convention ([0,1] x-range, REMOTE = x > 1) is
/// enforced here, and duplicate x-coordinates (general-position violation)
/// mark the request for the serial-exact path.  With `prefilter` set,
/// interior points are dropped by the octagon pre-filter first (the hull
/// is unchanged; the count lands in `Prepared::filtered`).
pub fn prepare(req: &HullRequest, prefilter: bool) -> Result<Prepared, RequestError> {
    if req.points.is_empty() {
        return Err(RequestError::Empty);
    }
    validate_points(&req.points)?;
    let mut pts: Vec<Point> = req.points.iter().map(|p| p.quantize_f32()).collect();
    sort_by_x(&mut pts);
    pts.dedup(); // exact duplicates can always be dropped
    let filtered = if prefilter { octagon_filter(&mut pts) } else { 0 };
    let degenerate = pts.windows(2).any(|w| w[0].x == w[1].x);
    Ok(Prepared { id: req.id, points: pts, degenerate, filtered, deadline: req.deadline })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::serial::monotone_chain;

    fn req(v: &[(f64, f64)]) -> HullRequest {
        HullRequest::new(1, v.iter().map(|&(x, y)| Point::new(x, y)).collect())
    }

    #[test]
    fn sorts_and_quantizes() {
        let p = prepare(&req(&[(0.9, 0.1), (0.1, 0.9)]), false).unwrap();
        assert!(p.points[0].x < p.points[1].x);
        assert!(!p.degenerate);
        for pt in &p.points {
            assert_eq!(pt.x, pt.x as f32 as f64);
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(matches!(prepare(&req(&[]), false), Err(RequestError::Empty)));
        assert!(matches!(
            prepare(&req(&[(f64::NAN, 0.0)]), false),
            Err(RequestError::NonFinite(0))
        ));
        assert!(matches!(
            prepare(&req(&[(0.5, 0.5), (1.5, 0.0)]), false),
            Err(RequestError::OutOfRange(1))
        ));
    }

    #[test]
    fn exact_duplicates_dropped() {
        let p = prepare(&req(&[(0.5, 0.5), (0.5, 0.5), (0.2, 0.2)]), false).unwrap();
        assert_eq!(p.points.len(), 2);
        assert!(!p.degenerate);
    }

    #[test]
    fn duplicate_x_flags_degenerate() {
        let p = prepare(&req(&[(0.5, 0.1), (0.5, 0.9), (0.2, 0.2)]), false).unwrap();
        assert_eq!(p.points.len(), 3);
        assert!(p.degenerate);
    }

    #[test]
    fn quantization_collision_detected() {
        // two doubles that collide in f32 become a duplicate and are merged
        let a = 0.1f64;
        let b = f64::from_bits(a.to_bits() + 1);
        let p = prepare(&req(&[(a, 0.3), (b, 0.3)]), false).unwrap();
        assert_eq!(p.points.len(), 1);
    }

    // ------------------------------------------------------- prefilter

    #[test]
    fn prefilter_preserves_hull_on_every_distribution() {
        for dist in Distribution::ALL {
            for &(n, seed) in &[(64usize, 1u64), (500, 2), (4096, 3)] {
                let pts = generate(dist, n, seed);
                let raw = HullRequest::new(1, pts);
                let plain = prepare(&raw, false).unwrap();
                let filt = prepare(&raw, true).unwrap();
                assert_eq!(
                    monotone_chain::full_hull(&plain.points),
                    monotone_chain::full_hull(&filt.points),
                    "{} n={n} hull changed by prefilter",
                    dist.name()
                );
                assert_eq!(plain.points.len(), filt.points.len() + filt.filtered);
            }
        }
    }

    #[test]
    fn prefilter_sheds_interior_points_on_dense_input() {
        let pts = generate(Distribution::Disk, 4096, 7);
        let p = prepare(&HullRequest::new(1, pts), true).unwrap();
        assert!(
            p.filtered > 2048,
            "dense disk kept {} of 4096 points",
            p.points.len()
        );
        // output must remain sorted for the backends
        assert!(p.points.windows(2).all(|w| w[0].x <= w[1].x));
    }

    #[test]
    fn prefilter_skips_small_inputs() {
        let pts = generate(Distribution::Disk, PREFILTER_MIN_POINTS - 1, 7);
        let p = prepare(&HullRequest::new(1, pts), true).unwrap();
        assert_eq!(p.filtered, 0);
    }

    #[test]
    fn prefilter_keeps_octagon_boundary_points() {
        // the four unit-square corners collapse the octagon to the square
        // itself; (0.5, 0) lies exactly ON its bottom edge and must be
        // kept (the interior test is strict), while (0.5, 0.5) is
        // strictly inside and must go
        let mut v: Vec<(f64, f64)> = vec![
            (0.0, 0.0),
            (1.0, 0.0),
            (1.0, 1.0),
            (0.0, 1.0),
            (0.5, 0.0),
            (0.5, 0.5),
        ];
        for k in 0..40 {
            v.push((0.25 + 0.01 * k as f64, 0.4)); // interior filler
        }
        let p = prepare(&req(&v), true).unwrap();
        assert!(
            p.points.contains(&Point::new(0.5, 0.0)),
            "boundary point dropped by prefilter"
        );
        assert!(
            !p.points.contains(&Point::new(0.5, 0.5)),
            "interior point survived the prefilter"
        );
    }

    #[test]
    fn prefilter_never_drops_hull_vertices_randomized() {
        for seed in 0..20u64 {
            let pts = generate(Distribution::ALL[(seed % 7) as usize], 777, seed);
            let raw = HullRequest::new(1, pts);
            let plain = prepare(&raw, false).unwrap();
            let filt = prepare(&raw, true).unwrap();
            let (u, l) = monotone_chain::full_hull(&plain.points);
            for hv in u.iter().chain(l.iter()) {
                assert!(filt.points.contains(hv), "hull vertex {hv} filtered out");
            }
        }
    }
}
