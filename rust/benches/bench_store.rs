//! E12 — durable sessions: what a checkpoint costs and what durability
//! does to streaming throughput.
//!
//! Three groups:
//!   * snapshot write latency vs session size — cold (every chunk is
//!     new) vs warm (steady state: the content-addressed store already
//!     holds yesterday's chunks, so the write is hash + dedup probe);
//!   * restore latency vs session size (read + decode + verify);
//!   * the merge-heavy schedule from E8 with checkpointing off, on a
//!     MemStore, and on an FsStore — the end-to-end overhead a session
//!     pays for crash durability.
//!
//! Run: `cargo bench --bench bench_store` (tier1.sh feeds
//! BENCH_store.json via WAGENER_BENCH_JSON; WAGENER_BENCH_FAST=1
//! shrinks the point counts).

use std::path::PathBuf;
use std::sync::Arc;

use wagener_hull::benchkit::{black_box, Bencher, Report};
use wagener_hull::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::store::{self, FsStore, MemStore, SessionState, SnapshotStore};
use wagener_hull::stream::{SessionRegistry, StreamConfig};

fn native_coord() -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Native,
            ..Default::default()
        })
        .unwrap(),
    )
}

/// Scratch directory for the FsStore rows, removed on drop.
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let path = std::env::temp_dir()
            .join(format!("wagener-bench-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).unwrap();
        TempDir(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Produce the realistic checkpoint state of a session that streamed
/// `n` disk points through a merge-heavy schedule: run it for real and
/// read back the close-time snapshot.
fn session_state(n: usize, threshold: usize) -> SessionState {
    let coord = native_coord();
    let store: Arc<MemStore> = Arc::new(MemStore::new());
    let reg = SessionRegistry::new_striped_with_store(
        StreamConfig { merge_threshold: threshold, idle_ttl_ms: 0, ..Default::default() },
        coord.metrics.clone(),
        1,
        1,
        Some(store.clone()),
    );
    let pts = generate(Distribution::Disk, n, 4242);
    let sid = reg.open().unwrap();
    for chunk in pts.chunks(1024) {
        reg.add(sid, chunk, &*coord).unwrap();
    }
    reg.close(sid, &*coord).unwrap();
    store::read_snapshot(&*store, sid).unwrap().unwrap()
}

fn main() {
    let b = Bencher::default();
    let fast = std::env::var("WAGENER_BENCH_FAST").is_ok();
    let sizes: &[usize] = if fast { &[1 << 12, 1 << 14] } else { &[1 << 12, 1 << 14, 1 << 16] };

    let mut report = Report::new(
        "E12: snapshot store — checkpoint write/restore latency vs session size",
    );
    for &n in sizes {
        let state = session_state(n, 1024);
        let report_bytes = {
            let probe = MemStore::new();
            store::write_snapshot(&probe, 1, &state).unwrap().bytes_written
        };
        report.note(format!(
            "n={n}: hull {}+{} pts, ledger {} epochs, cold snapshot {} bytes",
            state.upper.len(),
            state.lower.len(),
            state.ledger.len(),
            report_bytes,
        ));

        // cold: every chunk is new to the store (first checkpoint ever)
        let st = state.clone();
        report.add(b.run(&format!("store/write_mem_cold_n{n}"), move || {
            let fresh = MemStore::new();
            black_box(store::write_snapshot(&fresh, 1, &st).unwrap().bytes_written)
        }));

        // warm: steady state — the previous checkpoint's chunks are
        // already present, so writes are hash + dedup probe + manifest
        let warm = MemStore::new();
        store::write_snapshot(&warm, 1, &state).unwrap();
        let st = state.clone();
        report.add(b.run(&format!("store/write_mem_warm_n{n}"), move || {
            black_box(store::write_snapshot(&warm, 1, &st).unwrap().bytes_written)
        }));

        // restore: manifest read + chunk fetch + integrity verify + decode
        let full = MemStore::new();
        store::write_snapshot(&full, 1, &state).unwrap();
        report.add(b.run(&format!("store/restore_mem_n{n}"), move || {
            black_box(store::read_snapshot(&full, 1).unwrap().unwrap().epoch)
        }));
    }

    // FsStore rows at the largest size: the same write/restore but with
    // tmp-file + fsync-less rename commit on a real filesystem
    {
        let n = *sizes.last().unwrap();
        let state = session_state(n, 1024);
        let dir = TempDir::new("latency");
        let fs = FsStore::open(&dir.0).unwrap();
        store::write_snapshot(&fs, 1, &state).unwrap();
        let st = state.clone();
        let fs2 = FsStore::open(&dir.0).unwrap();
        report.add(b.run(&format!("store/write_fs_warm_n{n}"), move || {
            black_box(store::write_snapshot(&fs2, 1, &st).unwrap().bytes_written)
        }));
        report.add(b.run(&format!("store/restore_fs_n{n}"), move || {
            black_box(store::read_snapshot(&fs, 1).unwrap().unwrap().epoch)
        }));
    }
    report.finish();

    // end-to-end: the E8 merge-heavy schedule with durability off vs on
    let n = if fast { 1 << 13 } else { 1 << 15 };
    let pts = generate(Distribution::Disk, n, 21);
    let mut report = Report::new(&format!(
        "E12b: merge-heavy session (threshold=1024, disk n={n}) — checkpointing off vs on"
    ));
    let dir = TempDir::new("throughput");
    let stores: [(&str, Option<Arc<dyn SnapshotStore>>); 3] = [
        ("off", None),
        ("mem", Some(Arc::new(MemStore::new()))),
        ("fs", Some(Arc::new(FsStore::open(&dir.0).unwrap()))),
    ];
    for (label, store) in stores {
        let coord = native_coord();
        let reg = SessionRegistry::new_striped_with_store(
            StreamConfig { merge_threshold: 1024, idle_ttl_ms: 0, ..Default::default() },
            coord.metrics.clone(),
            1,
            1,
            store,
        );
        let pts2 = pts.clone();
        let coord2 = coord.clone();
        report.add(b.run(&format!("store/session_checkpoint_{label}_n{n}"), move || {
            let sid = reg.open().unwrap();
            for chunk in pts2.chunks(1024) {
                reg.add(sid, chunk, &*coord2).unwrap();
            }
            let snap = reg.hull(sid, &*coord2).unwrap();
            reg.close(sid, &*coord2).unwrap();
            black_box(snap.upper.len())
        }));
        let snap = coord.snapshot().0;
        report.note(format!(
            "{label}: snapshots_written={} snapshot_bytes={}",
            snap.get("snapshots_written_total").unwrap(),
            snap.get("snapshot_bytes_total").unwrap(),
        ));
    }
    report.finish();
}
