//! Geometric substrate: points, REMOTE/hood conventions, robust
//! orientation predicates, hull verification, workload generators.

pub mod generators;
pub mod hull_check;
pub mod point;
pub mod predicates;

pub use point::{Point, LIVE_X_MAX, REMOTE};
pub use predicates::{orient2d, Orientation};
