//! Visualisation: the paper's trace format (`show_current_hoods`) and a
//! `hood2ps`-equivalent renderer targeting SVG (Figures 1 & 4).

pub mod svg;
pub mod trace;

pub use svg::render_hull_svg;
pub use trace::{format_hoods, parse_trace, TraceWriter};
