"""Pure-numpy correctness oracles for Wagener's upper-hood pipeline.

These are deliberately *independent* of the Wagener logic: the per-stage
oracle recomputes each block's upper hull with a monotone chain, so a bug in
the g/f tangent phases cannot be mirrored here.

Conventions (paper §2):
  * points are x-sorted, coordinates in [0, 1];
  * a "hood" array stores, per block of ``d`` slots, the upper-hull corners
    of that block's points, left-justified and padded with REMOTE = (10, 0);
  * any slot with x > 1 is dead ("remote").

Orientation determinants are evaluated in float64 (inputs stay float32):
the paper assumes exact arithmetic ("it's a problem, but it's not our
problem"); float64 makes misclassification probability negligible for
continuous random data, and the rust side uses exact adaptive predicates.
"""

from __future__ import annotations

import numpy as np

REMOTE_X = 10.0
REMOTE_Y = 0.0
LIVE_X_MAX = 1.0  # slot is live iff x <= LIVE_X_MAX

LOW, EQUAL, HIGH = 0, 1, 2


def remote_row() -> np.ndarray:
    return np.array([REMOTE_X, REMOTE_Y], dtype=np.float32)


def is_live(pts: np.ndarray) -> np.ndarray:
    """Boolean liveness mask for an (..., 2) point array."""
    return pts[..., 0] <= LIVE_X_MAX


def left_of(p: np.ndarray, q: np.ndarray, r: np.ndarray) -> bool:
    """True iff r is strictly left of the directed segment p -> q."""
    p, q, r = (np.asarray(a, dtype=np.float64) for a in (p, q, r))
    return float(
        (q[0] - p[0]) * (r[1] - p[1]) - (q[1] - p[1]) * (r[0] - p[0])
    ) > 0.0


def upper_hull(points: np.ndarray) -> np.ndarray:
    """Monotone-chain upper hull of x-sorted points, strict turns.

    Input (m, 2); output (k, 2) hull corners left-to-right.  Collinear
    middle points are dropped (the paper assumes none exist).
    """
    pts = np.asarray(points, dtype=np.float32)
    if len(pts) <= 1:
        return pts.copy()
    stack: list[np.ndarray] = []
    for p in pts:
        # pop while the previous corner is not strictly above the chord
        while len(stack) >= 2 and not left_of(stack[-2], p, stack[-1]):
            stack.pop()
        stack.append(p)
    return np.stack(stack)


def pad_block(corners: np.ndarray, d: int) -> np.ndarray:
    """Left-justify corners in a d-slot block, REMOTE-padded."""
    out = np.tile(remote_row(), (d, 1))
    k = len(corners)
    if k:
        out[:k] = corners
    return out


def ref_stage(hood: np.ndarray, d: int) -> np.ndarray:
    """Oracle for one merge stage: hoods of size d -> hoods of size 2d.

    For every 2d-slot block, recompute the upper hull of its live corners
    from scratch (merging two hulls == hull of the union of their corners).
    """
    hood = np.asarray(hood, dtype=np.float32)
    n = hood.shape[0]
    assert n % (2 * d) == 0, (n, d)
    out = np.empty_like(hood)
    for b in range(n // (2 * d)):
        blk = hood[b * 2 * d : (b + 1) * 2 * d]
        live = blk[is_live(blk)]
        out[b * 2 * d : (b + 1) * 2 * d] = pad_block(upper_hull(live), 2 * d)
    return out


def ref_hood(points: np.ndarray) -> np.ndarray:
    """Full-pipeline oracle: n-slot hood block of the upper hull."""
    pts = np.asarray(points, dtype=np.float32)
    n = pts.shape[0]
    live = pts[is_live(pts)]
    return pad_block(upper_hull(live), n)


def ref_lower_hood(points: np.ndarray) -> np.ndarray:
    """Lower hull as an n-slot hood (left-to-right order).

    Computed as the upper hull of y-negated points, then y restored.
    REMOTE slots stay (10, 0).
    """
    pts = np.asarray(points, dtype=np.float32)
    neg = pts.copy()
    neg[:, 1] = -neg[:, 1]
    hood = ref_hood(neg)
    livem = is_live(hood)
    hood[livem, 1] = -hood[livem, 1]
    return hood


def ref_tangent(pblk: np.ndarray, qblk: np.ndarray) -> tuple[int, int]:
    """Brute-force common upper tangent between two hood blocks.

    Returns (pi, qi): indices into pblk / qblk of the tangent corners:
    the unique pair (a, b) such that every other live corner of both blocks
    lies strictly right of (below) the directed line a -> b.
    """
    plive = pblk[is_live(pblk)]
    qlive = qblk[is_live(qblk)]
    for ai in range(len(plive)):
        for bi in range(len(qlive)):
            a, b = plive[ai], qlive[bi]
            ok = True
            for other in list(plive) + list(qlive):
                if np.array_equal(other, a) or np.array_equal(other, b):
                    continue
                if left_of(a, b, other):
                    ok = False
                    break
            if ok:
                return ai, bi
    raise AssertionError("no common tangent found (degenerate input?)")
