//! E8 — streaming sessions: incremental maintenance vs one-shot re-hull.
//!
//! Two schedules over n = 2^16 disk points through a session on the
//! native backend:
//!   * insert-heavy — a high merge threshold, so after the first re-hull
//!     almost every insert is an O(log h) interior rejection;
//!   * merge-heavy — a low threshold, so the tangent/interleave merge
//!     path and the backend round-trip dominate.
//! Plus the `merge_hulls` micro rows (tangent vs interleave) and the
//! one-shot baseline the session numbers are judged against.
//!
//! Run: `cargo bench --bench bench_stream` (tier1.sh feeds
//! BENCH_stream.json via WAGENER_BENCH_JSON).

use std::sync::Arc;

use wagener_hull::benchkit::{black_box, Bencher, Report};
use wagener_hull::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use wagener_hull::geometry::generators::{self, generate, Distribution};
use wagener_hull::geometry::point::Point;
use wagener_hull::serial::monotone_chain;
use wagener_hull::stream::{SessionRegistry, StreamConfig};
use wagener_hull::wagener::hull_merge::{merge_hulls, MergePath};

fn native_coord() -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Native,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn main() {
    let b = Bencher::default();
    let n = 1usize << 16;
    let pts = generate(Distribution::Disk, n, 21);

    let mut report = Report::new("E8: streaming sessions (native backend, disk n=2^16)");

    // one-shot baseline: what a stateless server pays on EVERY update
    {
        let coord = native_coord();
        let pts2 = pts.clone();
        report.add(b.run("stream/oneshot_rehull_n65536", move || {
            coord.compute(pts2.clone()).unwrap()
        }));
    }

    for (name, threshold) in [("insert_heavy", 16384usize), ("merge_heavy", 1024)] {
        let coord = native_coord();
        let registry = SessionRegistry::new(
            StreamConfig { merge_threshold: threshold, idle_ttl_ms: 0, ..Default::default() },
            coord.metrics.clone(),
        );
        let pts2 = pts.clone();
        let coord2 = coord.clone();
        report.add(b.run(&format!("stream/{name}_n65536_batch1024"), move || {
            let sid = registry.open().unwrap();
            for chunk in pts2.chunks(1024) {
                registry.add(sid, chunk, &*coord2).unwrap();
            }
            let snap = registry.hull(sid, &*coord2).unwrap();
            registry.close(sid, &*coord2).unwrap();
            black_box(snap.upper.len())
        }));
        let snap = coord.snapshot().0;
        report.note(format!(
            "{name}: threshold={threshold} absorbed={} merges={}",
            snap.get("absorbed_points_total").unwrap(),
            snap.get("merges_total").unwrap(),
        ));
    }
    report.finish();

    // merge_hulls micro rows: hull ⊕ hull combine cost on both paths
    let mut report = Report::new("E8b: merge_hulls (hull ⊕ hull combine)");
    let squeeze = |pts: &[Point], lo: f64, hi: f64| -> Vec<Point> {
        let mut v = generators::squeeze_x(pts, lo, hi);
        wagener_hull::geometry::point::sort_by_x(&mut v);
        // the squeeze can collide distinct x's on the f32 grid; the
        // serial chains (and merge_hulls' precondition) want distinct x
        v.dedup_by(|p, q| p.x == q.x);
        v
    };
    let base_a = generate(Distribution::Circle, 4096, 31);
    let base_b = generate(Distribution::Circle, 4096, 32);
    for (row, (alo, ahi), (blo, bhi), want) in [
        ("tangent_disjoint", (0.0, 0.47), (0.53, 1.0), MergePath::Tangent),
        ("interleave_overlap", (0.0, 0.8), (0.2, 1.0), MergePath::Interleave),
    ] {
        let a = squeeze(&base_a, alo, ahi);
        let b2 = squeeze(&base_b, blo, bhi);
        let (au, al) = monotone_chain::full_hull(&a);
        let (bu, bl) = monotone_chain::full_hull(&b2);
        let ((_, _), path) = merge_hulls((&au, &al), (&bu, &bl));
        assert_eq!(path, want, "{row} exercised the wrong path");
        report.add(b.run(&format!("merge_hulls/{row}_h{}x{}", au.len(), bu.len()), || {
            black_box(merge_hulls(
                (black_box(&au), black_box(&al)),
                (black_box(&bu), black_box(&bl)),
            ))
        }));
    }
    report.finish();
}
