//! Cross-implementation agreement: every hull path in the repo computes
//! the same answer on the same inputs (serial x3, gift-wrap, native
//! Wagener, PRAM Wagener, OvL-optimal), across distributions and sizes.

use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::hull_check::check_upper_hull;
use wagener_hull::geometry::point::live_prefix;
use wagener_hull::ovl;
use wagener_hull::serial::{gift_wrapping, graham, monotone_chain, quickhull};
use wagener_hull::wagener;

#[test]
fn all_implementations_agree() {
    for dist in Distribution::ALL {
        for &n in &[3usize, 17, 100, 512] {
            let pts = generate(dist, n, 0xC0FFEE);
            let want = monotone_chain::upper_hull(&pts);
            check_upper_hull(&pts, &want).unwrap();

            assert_eq!(quickhull::upper_hull(&pts), want, "quickhull {} {n}", dist.name());
            assert_eq!(
                gift_wrapping::upper_hull(&pts),
                want,
                "giftwrap {} {n}",
                dist.name()
            );
            assert_eq!(
                graham::upper_chain(&graham::convex_hull(&pts)),
                want,
                "graham {} {n}",
                dist.name()
            );
            assert_eq!(wagener::upper_hull(&pts), want, "wagener {} {n}", dist.name());
            assert_eq!(
                ovl::optimal_upper_hull(&pts, 0).hull,
                want,
                "ovl {} {n}",
                dist.name()
            );
            let slots = n.next_power_of_two().max(2);
            let pram = wagener::pram_exec::run_pipeline(&pts, slots).unwrap();
            assert_eq!(live_prefix(&pram.hood), &want[..], "pram {} {n}", dist.name());
        }
    }
}

#[test]
fn pram_counters_match_theory_across_sizes() {
    // time Θ(log n): 8 steps per stage; work Θ(n log n): 8 * n/2 per stage
    for &n in &[16usize, 64, 256, 1024] {
        let pts = generate(Distribution::UniformSquare, n, 3);
        let run = wagener::pram_exec::run_pipeline(&pts, n).unwrap();
        let stages = (n.trailing_zeros() - 1) as u64;
        assert_eq!(run.counters.steps, 8 * stages, "n={n}");
        assert_eq!(run.counters.work, 8 * stages * (n as u64 / 2), "n={n}");
        assert_eq!(run.counters.write_conflicts, 0, "n={n}");
    }
}

#[test]
fn figure4_scenario_1024_points() {
    // the paper's sample run: 1024 points end-to-end on every path
    let pts = generate(Distribution::Disk, 1024, 42);
    let want = monotone_chain::upper_hull(&pts);
    assert_eq!(wagener::upper_hull(&pts), want);
    let run = wagener::pram_exec::run_pipeline(&pts, 1024).unwrap();
    assert_eq!(live_prefix(&run.hood), &want[..]);
    assert_eq!(run.per_stage.len(), 9);
    // occupancy table exists for all 9 stages (Figure 2)
    let occ = wagener::occupancy::occupancy_table(&pts, 1024);
    assert_eq!(occ.len(), 9);
}
