#!/usr/bin/env python3
"""Differential simulation of rust/src/gateway/{http,cursor}.rs.

Transliterates the incremental HTTP/1.1 request decoder (head scan,
percent-decoding, Content-Length and chunked framing, keep-alive
negotiation, the smuggling rejections) and the pagination cursor codec
(xor-fold checksum, hex wire form, the page slicer), then property-tests
both:

  * one-shot vs incremental consistency: a valid request decodes whole
    with used == len(wire); every strict prefix is Need(n) with n >
    len(prefix) and never a phantom frame or an error;
  * bounded progress on arbitrary bytes: Need(n) always satisfies
    n > len(buf) and n <= max(len(buf), MAX_HEAD_BYTES) + max_body + 2,
    and nothing ever raises;
  * oversized Content-Length and every classic smuggling vector
    (TE+CL, conflicting duplicate CLs, obs-folding) are fatal errors
    with the documented codes, never Need;
  * cursor encode/decode identity, canonical accepts, tamper rejection;
  * pagination parity: for random chains and random *per-page* limit
    schedules, concatenating pages yields exactly upper ++ lower with
    the epoch pinned on every resume cursor.
"""

import random
import re
import sys

MAX_HEAD_BYTES = 16 * 1024
MAX_HEADERS = 64

# decode results: ("need", n) | ("frame", request, used) | ("err", code)
NEED, FRAME, ERR = "need", "frame", "err"


def head_end(buf):
    """Index just past the first blank line (CRLF or bare-LF)."""
    i = 0
    while i < len(buf):
        if buf[i] == 0x0A:
            rest = buf[i + 1:]
            if rest[:1] == b"\n":
                return i + 2
            if len(rest) >= 2 and rest[0] == 0x0D and rest[1] == 0x0A:
                return i + 3
        i += 1
    return None


def percent_decode(s):
    bts = s.encode("utf-8")
    out = bytearray()
    i = 0
    while i < len(bts):
        b = bts[i]
        if b == ord("%") and i + 2 < len(bts):
            try:
                out.append(int(bts[i + 1:i + 3].decode(), 16))
                i += 3
                continue
            except ValueError:
                out.append(ord("%"))
                i += 1
                continue
        if b == ord("+"):
            out.append(ord(" "))
        else:
            out.append(b)
        i += 1
    return out.decode("utf-8", errors="replace")


def parse_query(qs):
    out = []
    for pair in qs.split("&"):
        if not pair:
            continue
        if "=" in pair:
            k, v = pair.split("=", 1)
            out.append((percent_decode(k), percent_decode(v)))
        else:
            out.append((percent_decode(pair), ""))
    return out


def parse_uint(s):
    """Rust's usize::from_str: optional '+', digits only."""
    return int(s) if re.fullmatch(r"\+?[0-9]+", s) else None


def parse_hex(s):
    return int(s, 16) if re.fullmatch(r"\+?[0-9a-fA-F]+", s) else None


def strip_cr(line):
    return line[:-1] if line.endswith("\r") else line


def decode_chunked(buf, max_body):
    body = bytearray()
    off = 0
    while True:
        nl = buf.find(b"\n", off)
        if nl < 0:
            if len(buf) - off > 18:
                return (ERR, "bad-chunk")
            return (NEED, len(buf) + 1)
        try:
            line = strip_cr(buf[off:nl].decode("utf-8"))
        except UnicodeDecodeError:
            return (ERR, "bad-chunk")
        size_hex = line.split(";")[0].strip()
        if not size_hex or len(size_hex) > 8:
            return (ERR, "bad-chunk")
        size = parse_hex(size_hex)
        if size is None:
            return (ERR, "bad-chunk")
        off = nl + 1
        if size == 0:
            rest = buf[off:]
            if not rest or (rest[0] == 0x0D and len(rest) < 2):
                return (NEED, len(buf) + 1)
            if rest[0] == 0x0A:
                return (FRAME, bytes(body), off + 1)
            if rest[0] == 0x0D and rest[1] == 0x0A:
                return (FRAME, bytes(body), off + 2)
            return (ERR, "bad-chunk")
        if len(body) + size > max_body:
            return (ERR, "body-too-large")
        if len(buf) < off + size + 1:
            return (NEED, off + size + 1)
        body.extend(buf[off:off + size])
        off += size
        if buf[off] == 0x0A:
            off += 1
        elif buf[off] == 0x0D:
            if len(buf) < off + 2:
                return (NEED, off + 2)
            if buf[off + 1] != 0x0A:
                return (ERR, "bad-chunk")
            off += 2
        else:
            return (ERR, "bad-chunk")


def decode_request(buf, max_body):
    hl = head_end(buf)
    if hl is None:
        if len(buf) >= MAX_HEAD_BYTES:
            return (ERR, "headers-too-large")
        return (NEED, len(buf) + 1)
    if hl > MAX_HEAD_BYTES:
        return (ERR, "headers-too-large")
    try:
        head = buf[:hl].decode("utf-8")
    except UnicodeDecodeError:
        return (ERR, "malformed-request")
    lines = [strip_cr(l) for l in head.split("\n")]

    parts = [p for p in lines[0].split(" ") if p]
    if len(parts) != 3:
        return (ERR, "malformed-request")
    method, target, version = parts
    if version == "HTTP/1.1":
        http11 = True
    elif version == "HTTP/1.0":
        http11 = False
    else:
        return (ERR, "unsupported-version")
    if not target.startswith("/"):
        return (ERR, "malformed-request")
    raw_path, _, raw_query = target.partition("?")

    headers = []
    for line in lines[1:]:
        if not line:
            continue
        if len(headers) >= MAX_HEADERS:
            return (ERR, "headers-too-large")
        if line[0] in (" ", "\t"):
            return (ERR, "ambiguous-framing")
        if ":" not in line:
            return (ERR, "malformed-request")
        name, value = line.split(":", 1)
        if not name or " " in name or "\t" in name:
            return (ERR, "malformed-request")
        headers.append((name.lower(), value.strip()))

    te = [v for n, v in headers if n == "transfer-encoding"]
    cl = [v for n, v in headers if n == "content-length"]
    if te and cl:
        return (ERR, "ambiguous-framing")
    if len(cl) > 1 and any(v != cl[0] for v in cl):
        return (ERR, "ambiguous-framing")

    if te:
        if len(te) > 1 or te[0].lower() != "chunked":
            return (ERR, "ambiguous-framing")
        got = decode_chunked(buf[hl:], max_body)
        if got[0] == NEED:
            return (NEED, hl + got[1])
        if got[0] == ERR:
            return got
        body, used = got[1], hl + got[2]
    elif cl:
        n = parse_uint(cl[0])
        if n is None:
            return (ERR, "malformed-request")
        if n > max_body:
            return (ERR, "body-too-large")
        if len(buf) < hl + n:
            return (NEED, hl + n)
        body, used = bytes(buf[hl:hl + n]), hl + n
    else:
        body, used = b"", hl

    conn = next((v.lower() for n, v in headers if n == "connection"), None)
    if conn is not None and any(t.strip() == "close" for t in conn.split(",")):
        keep_alive = False
    elif conn is not None and any(t.strip() == "keep-alive" for t in conn.split(",")):
        keep_alive = True
    else:
        keep_alive = http11

    req = {
        "method": method,
        "path": percent_decode(raw_path),
        "query": parse_query(raw_query),
        "headers": headers,
        "body": body,
        "keep_alive": keep_alive,
    }
    return (FRAME, req, used)


# ------------------------------------------------------------- cursors

CURSOR_VERSION = 1
RAW_LEN = 1 + 8 + 1 + 8 + 1


def rotl8(b, k):
    return ((b << k) | (b >> (8 - k))) & 0xFF


def checksum(raw):
    acc = 0x5A
    for b in raw:
        acc ^= rotl8(b, 3)
    return acc


def cursor_encode(epoch, chain, offset):
    raw = bytearray(RAW_LEN)
    raw[0] = CURSOR_VERSION
    raw[1:9] = epoch.to_bytes(8, "little")
    raw[9] = chain
    raw[10:18] = offset.to_bytes(8, "little")
    raw[18] = checksum(raw[:18])
    return raw.hex()

def cursor_decode(s):
    if len(s) != RAW_LEN * 2 or not re.fullmatch(r"[0-9a-fA-F]+", s):
        return None
    raw = bytes.fromhex(s)
    if raw[0] != CURSOR_VERSION or raw[18] != checksum(raw[:18]):
        return None
    chain = raw[9]
    if chain > 1:
        return None
    return (
        int.from_bytes(raw[1:9], "little"),
        chain,
        int.from_bytes(raw[10:18], "little"),
    )


def page(upper, lower, at, limit):
    """Mirror of cursor::page — returns (upper_slice, lower_slice, next)."""
    assert limit > 0
    epoch, chain, offset = at
    out_upper, out_lower = [], []
    room = limit
    if chain == 0:
        start = min(offset, len(upper))
        take = min(room, len(upper) - start)
        out_upper = upper[start:start + take]
        room -= take
        if start + take < len(upper):
            return out_upper, out_lower, (epoch, 0, start + take)
        chain, offset = 1, 0
    start = min(offset, len(lower))
    take = min(room, len(lower) - start)
    out_lower = lower[start:start + take]
    nxt = (epoch, 1, start + take) if start + take < len(lower) else None
    return out_upper, out_lower, nxt


# ----------------------------------------------------------- properties

def check(cond, msg):
    if not cond:
        print(f"FAIL: {msg}", file=sys.stderr)
        sys.exit(1)


def check_bounds(buf, max_body, got, what):
    if got[0] == NEED:
        check(got[1] > len(buf), f"{what}: Need({got[1]}) no progress at {len(buf)}")
        cap = max(len(buf), MAX_HEAD_BYTES) + max_body + 2
        check(got[1] <= cap, f"{what}: Need({got[1]}) over cap {cap}")
    elif got[0] == FRAME:
        check(0 < got[2] <= len(buf), f"{what}: used {got[2]} of {len(buf)}")


def valid_request(rng):
    """A random well-formed request; returns (wire, expected_body)."""
    method = rng.choice(["GET", "POST", "DELETE"])
    target = rng.choice([
        "/v1/hull",
        f"/v1/sessions/{rng.randrange(100)}/hull?epoch={rng.randrange(9)}&limit=7",
        "/v1/stats",
    ])
    wire = bytearray(f"{method} {target} HTTP/1.1\r\nhost: sim\r\n".encode())
    body = b""
    kind = rng.randrange(3)
    if kind == 0:
        wire += b"\r\n"
    elif kind == 1:
        body = bytes(rng.randrange(256) for _ in range(rng.randrange(65)))
        wire += f"content-length: {len(body)}\r\n\r\n".encode()
        wire += body
    else:
        wire += b"transfer-encoding: chunked\r\n\r\n"
        chunks = []
        for _ in range(rng.randrange(4)):
            c = bytes(rng.randrange(256) for _ in range(rng.randrange(1, 33)))
            chunks.append(c)
            wire += f"{len(c):x}\r\n".encode() + c + b"\r\n"
        wire += b"0\r\n\r\n"
        body = b"".join(chunks)
    return bytes(wire), body


def main():
    rng = random.Random(0xF0CC_51D0)

    # ---- valid corpus: whole decode + strict prefixes
    corpus = 0
    for _ in range(1500):
        wire, body = valid_request(rng)
        got = decode_request(wire, 1 << 20)
        check(got[0] == FRAME, f"valid request rejected: {got} for {wire!r}")
        check(got[2] == len(wire), f"used {got[2]} != {len(wire)}")
        check(got[1]["body"] == body, f"body mismatch for {wire!r}")
        check(got[1]["keep_alive"], "HTTP/1.1 without Connection must keep alive")
        for _ in range(6):
            cut = rng.randrange(len(wire))
            pre = decode_request(wire[:cut], 1 << 20)
            check(pre[0] == NEED, f"prefix {cut} of valid request: {pre}")
            check(pre[1] > cut, f"prefix Need({pre[1]}) no progress at {cut}")
        corpus += 1

    # ---- arbitrary bytes: bounded progress, no exceptions
    noise = 0
    for i in range(6000):
        n = rng.randrange(4097 if i % 50 == 0 else 97)
        buf = bytes(rng.randrange(256) for _ in range(n))
        for max_body in (0, 100, 1 << 20):
            check_bounds(buf, max_body, decode_request(buf, max_body), "noise")
        noise += 1

    # ---- oversized Content-Length: fatal from the header alone
    for _ in range(500):
        max_body = rng.randrange(1 << 16)
        declared = max_body + 1 + rng.randrange(1 << 32)
        wire = f"POST /v1/hull HTTP/1.1\r\ncontent-length: {declared}\r\n\r\n".encode()
        got = decode_request(wire, max_body)
        check(got == (ERR, "body-too-large"), f"declared {declared} cap {max_body}: {got}")

    # ---- smuggling vectors: always fatal with the one code
    for _ in range(500):
        a = rng.randrange(1 << 20)
        b = a + 1 + rng.randrange(1 << 10)
        for v in (
            f"content-length: {a}\r\ntransfer-encoding: chunked\r\n",
            f"transfer-encoding: chunked\r\ncontent-length: {a}\r\n",
            f"content-length: {a}\r\ncontent-length: {b}\r\n",
            "x: 1\r\n folded-continuation\r\n",
        ):
            wire = f"POST /v1/hull HTTP/1.1\r\n{v}\r\n".encode()
            got = decode_request(wire, 1 << 24)
            check(got == (ERR, "ambiguous-framing"), f"vector {v!r}: {got}")
    # identical duplicates still frame
    ok = decode_request(b"POST /x HTTP/1.1\r\ncontent-length: 2\r\ncontent-length: 2\r\n\r\nok",
                        1 << 24)
    check(ok[0] == FRAME and ok[1]["body"] == b"ok", f"benign dup CL: {ok}")

    # ---- chunked pathologies: unterminated size line, trailers, bad hex
    check(decode_chunked(b"x" * 19, 100) == (ERR, "bad-chunk"), "unterminated size line")
    check(decode_chunked(b"zz\r\n", 100) == (ERR, "bad-chunk"), "non-hex size")
    check(decode_chunked(b"0\r\nx-trailer: 1\r\n\r\n", 100) == (ERR, "bad-chunk"), "trailers")
    check(decode_chunked(b"3\r\nabcXX", 100) == (ERR, "bad-chunk"), "unterminated data")
    check(decode_chunked(b"123456789\r\n", 1 << 40) == (ERR, "bad-chunk"), "9-digit size")

    # ---- cursor codec: identity, canonical accepts, tamper rejection
    cursors = 0
    U64 = (1 << 64) - 1
    for _ in range(4000):
        c = (rng.randrange(1 << 64), rng.randrange(2), rng.randrange(1 << 64))
        wire = cursor_encode(*c)
        check(len(wire) == 38, f"wire length {len(wire)}")
        check(cursor_decode(wire) == c, f"roundtrip {c}")
        at = rng.randrange(38)
        repl = rng.choice("0123456789abcdef")
        if repl != wire[at]:
            tampered = wire[:at] + repl + wire[at + 1:]
            check(cursor_decode(tampered) is None, f"tamper at {at} survived: {tampered}")
        junk = "".join(rng.choice("0123456789abcdef") for _ in range(38))
        got = cursor_decode(junk)
        if got is not None:
            check(cursor_encode(*got) == junk, f"non-canonical accept {junk}")
        cursors += 1
    for c in ((0, 0, 0), (7, 1, 12345), (U64, 0, U64)):
        check(cursor_decode(cursor_encode(*c)) == c, f"vector {c}")

    # ---- pagination parity: random chains, random per-page limits
    walks = 0
    for _ in range(2000):
        epoch = rng.randrange(1 << 32)
        upper = [("u", i) for i in range(rng.randrange(40))]
        lower = [("l", i) for i in range(rng.randrange(40))]
        cur = (epoch, 0, 0)
        got_u, got_l, pages = [], [], 0
        while True:
            limit = rng.randrange(1, 9)
            pu, pl, nxt = page(upper, lower, cur, limit)
            check(len(pu) + len(pl) <= limit, f"page over limit {limit}")
            got_u += pu
            got_l += pl
            pages += 1
            check(pages <= len(upper) + len(lower) + 2, "walk never terminates")
            if nxt is None:
                break
            check(nxt[0] == epoch, f"epoch drifted: {nxt}")
            cur = nxt
        check(got_u == upper and got_l == lower,
              f"reassembly mismatch at {len(upper)}+{len(lower)}")
        # out-of-range offsets are exhausted, not errors
        pu, pl, nxt = page(upper, lower, (epoch, 1, len(lower) + 5), 3)
        check(pu == [] and pl == [] and nxt is None, "clamped resume")
        walks += 1

    print(f"sim_gateway OK: http corpus {corpus} + noise {noise}, "
          f"oversize/smuggling 500 each, cursors {cursors}, pagination walks {walks}")


if __name__ == "__main__":
    main()
