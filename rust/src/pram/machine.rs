//! The PRAM machine: synchronous steps over flat shared memory.
//!
//! Two execution tiers share one `step` API (see the module docs):
//!
//! * **Audited** — full CREW checking and 32-bank serialization modeling,
//!   with zero steady-state allocation: transaction logs and epoch-stamped
//!   shadow arrays are allocated once and reused, the write commit is
//!   sort-free (one pass in program order), and per-warp bank costs use
//!   fixed 32-slot counters.
//! * **Fast** — no read logging, no conflict detection, no bank model;
//!   large steps fan PEs out across scoped worker threads (spawned per
//!   step via `std::thread::scope` above `fast_parallel_threshold`)
//!   with per-worker write buffers merged at the step barrier.  This is
//!   the tier the coordinator/server path runs.

/// Shared-memory banks on every CUDA generation; per-warp bank counters
/// are fixed arrays of this size (the audited tier's zero-alloc core).
pub const MAX_BANKS: usize = 32;

/// Upper bound on fast-tier worker threads per step.
const MAX_FAST_WORKERS: usize = 16;

/// Which execution tier a [`Pram`] machine runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// CREW checking + bank-conflict cost model (experiments; the paper's
    /// instrument).  Serial PE dispatch, deterministic counters.
    #[default]
    Audited,
    /// Production tier: parallel PE dispatch, no access auditing.  Only
    /// `steps`, `work`, `max_pes` and the ideal/modeled cycle floor are
    /// maintained (a fast step is modeled conflict-free).
    Fast,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<ExecMode> {
        Some(match s {
            "audited" => ExecMode::Audited,
            "fast" => ExecMode::Fast,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Audited => "audited",
            ExecMode::Fast => "fast",
        }
    }
}

/// CUDA-style shared-memory serialization model.
#[derive(Clone, Copy, Debug)]
pub struct BankModel {
    /// number of shared-memory banks (32 on every CUDA generation;
    /// must be <= [`MAX_BANKS`]).
    pub banks: usize,
    /// SIMD width — PEs `[w*warp, (w+1)*warp)` form one warp.
    pub warp: usize,
    /// bank index stride in machine words (4-byte words on CUDA; our cells
    /// are one word each).  A pair (`float2`) access is one coalesced
    /// transaction at stride `2 * word_stride`.
    pub word_stride: usize,
}

impl Default for BankModel {
    fn default() -> Self {
        BankModel { banks: 32, warp: 32, word_stride: 1 }
    }
}

/// Aggregate counters over the life of the machine.
///
/// The fast tier maintains only `steps`, `work`, `max_pes`,
/// `ideal_cycles` and `modeled_cycles` (each step modeled conflict-free);
/// the access-level counters stay 0 there.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Counters {
    /// synchronous parallel steps executed (PRAM time).
    pub steps: u64,
    /// total PE activations (PRAM work).
    pub work: u64,
    /// shared-memory read / write *transactions*.  A `read_pair` /
    /// `write_pair` (CUDA `float2`) access counts as ONE coalesced
    /// transaction, matching the paper's vectorized loads.
    pub reads: u64,
    pub writes: u64,
    /// modeled cycles under the bank model (>= steps; == steps iff
    /// conflict-free).  One step costs `max over warps of (read
    /// serialization + write serialization)`, min 1.
    pub modeled_cycles: u64,
    /// ideal cycles: 1 per step (a conflict-free PRAM).
    pub ideal_cycles: u64,
    /// cells written by two or more PEs in one step (CREW violations),
    /// deduplicated per (step, cell): k writers to one cell in one step
    /// count once.
    pub write_conflicts: u64,
    /// read transactions touching a cell also written in the same step
    /// (benign under reads-see-old-memory semantics; diagnostics).
    pub read_write_overlaps: u64,
    /// largest PE count used in any step.
    pub max_pes: u64,
}

impl Counters {
    /// Bank-conflict slowdown factor (modeled / ideal).
    pub fn conflict_factor(&self) -> f64 {
        if self.ideal_cycles == 0 {
            1.0
        } else {
            self.modeled_cycles as f64 / self.ideal_cycles as f64
        }
    }
}

/// Hard errors (write-write conflicts when `strict` is set).
#[derive(Debug, Clone, PartialEq)]
pub struct PramError {
    pub step: u64,
    pub addr: usize,
    pub pes: (usize, usize),
}

impl std::fmt::Display for PramError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CREW violation at step {}: cell {} written by PEs {} and {}",
            self.step, self.addr, self.pes.0, self.pes.1
        )
    }
}

impl std::error::Error for PramError {}

/// One buffered cell write (commits at the step barrier).
#[derive(Clone, Copy, Debug)]
struct CellWrite {
    addr: usize,
    val: f64,
    pe: u32,
}

/// One shared-memory transaction (audited tier only).  `wide` marks a
/// pair (`float2`) access covering cells `addr` and `addr + 1`.
#[derive(Clone, Copy, Debug)]
struct Xact {
    addr: usize,
    pe: u32,
    wide: bool,
}

/// Reusable transaction logs (audited tier; cleared, never reallocated).
#[derive(Default)]
struct XactLog {
    reads: Vec<Xact>,
    writes: Vec<Xact>,
}

/// Epoch-stamped shadow arrays: all per-step bookkeeping without per-step
/// allocation or sorting.  A stamp equal to the current epoch means "seen
/// this step/warp"; bumping the epoch invalidates every stamp in O(1).
#[derive(Default)]
struct AuditScratch {
    step_epoch: u64,
    warp_epoch: u64,
    /// per cell: step epoch of the last buffered write (CREW detection).
    write_stamp: Vec<u64>,
    /// per cell: first writer PE of the current step.
    write_pe: Vec<u32>,
    /// per cell: step epoch in which a conflict was already counted
    /// (dedupe: one conflict per (step, cell)).
    conflict_stamp: Vec<u64>,
    /// per (cell, width) key `addr << 1 | wide`: warp epoch of the last
    /// occurrence (CUDA broadcast — duplicate addresses in a warp count
    /// once per bank).
    seen_stamp: Vec<u64>,
}

impl AuditScratch {
    fn ensure(&mut self, cells: usize) {
        if self.write_stamp.len() < cells {
            self.write_stamp.resize(cells, 0);
            self.write_pe.resize(cells, 0);
            self.conflict_stamp.resize(cells, 0);
        }
        if self.seen_stamp.len() < 2 * cells {
            self.seen_stamp.resize(2 * cells, 0);
        }
    }
}

/// Per-PE execution context handed to the step closure.
pub struct PeCtx<'a> {
    pe: usize,
    mem: &'a [f64],
    regs: &'a mut [f64],
    writes: &'a mut Vec<CellWrite>,
    /// `Some` on the audited tier; the fast tier logs nothing.
    audit: Option<&'a mut XactLog>,
}

impl<'a> PeCtx<'a> {
    pub fn pe(&self) -> usize {
        self.pe
    }

    /// Read a shared cell (sees the memory state before this step).
    pub fn read(&mut self, addr: usize) -> f64 {
        if let Some(log) = self.audit.as_deref_mut() {
            log.reads.push(Xact { addr, pe: self.pe as u32, wide: false });
        }
        self.mem[addr]
    }

    /// Buffer a shared-cell write (commits at the step barrier).
    pub fn write(&mut self, addr: usize, val: f64) {
        if let Some(log) = self.audit.as_deref_mut() {
            log.writes.push(Xact { addr, pe: self.pe as u32, wide: false });
        }
        self.writes.push(CellWrite { addr, val, pe: self.pe as u32 });
    }

    /// Read a 2-cell point (x at `addr2`, y at `addr2 + 1`) as ONE
    /// coalesced transaction (CUDA `float2` load, word_stride 2).
    pub fn read_pair(&mut self, addr2: usize) -> (f64, f64) {
        if let Some(log) = self.audit.as_deref_mut() {
            log.reads.push(Xact { addr: addr2, pe: self.pe as u32, wide: true });
        }
        (self.mem[addr2], self.mem[addr2 + 1])
    }

    /// Write a 2-cell point as ONE coalesced transaction (both cells still
    /// commit — and CREW-check — individually).
    pub fn write_pair(&mut self, addr2: usize, x: f64, y: f64) {
        if let Some(log) = self.audit.as_deref_mut() {
            log.writes.push(Xact { addr: addr2, pe: self.pe as u32, wide: true });
        }
        self.writes.push(CellWrite { addr: addr2, val: x, pe: self.pe as u32 });
        self.writes.push(CellWrite { addr: addr2 + 1, val: y, pe: self.pe as u32 });
    }

    /// Private per-PE register file (not shared memory; not counted).
    pub fn reg(&self, r: usize) -> f64 {
        self.regs[r]
    }

    pub fn set_reg(&mut self, r: usize, v: f64) {
        self.regs[r] = v;
    }
}

/// The machine.
pub struct Pram {
    pub mem: Vec<f64>,
    pub counters: Counters,
    pub bank_model: BankModel,
    /// return Err on write-write conflicts instead of counting
    /// (audited tier only; the fast tier never detects conflicts).
    pub strict: bool,
    /// execution tier; see [`ExecMode`].
    pub mode: ExecMode,
    /// fast tier: steps with fewer PEs than this run on the calling
    /// thread (scoped worker threads don't pay for themselves on small
    /// launches).
    pub fast_parallel_threshold: usize,
    regs: Vec<f64>,
    regs_per_pe: usize,
    writes_buf: Vec<CellWrite>,
    audit_log: XactLog,
    scratch: AuditScratch,
    worker_bufs: Vec<Vec<CellWrite>>,
    /// `available_parallelism()` sampled once at construction (the call
    /// is a syscall; the fast tier consults it every step).
    hw_threads: usize,
}

impl Pram {
    /// `cells` words of shared memory; `regs_per_pe` private registers for
    /// up to `max_pes` PEs.  Runs the audited tier.
    pub fn new(cells: usize, max_pes: usize, regs_per_pe: usize) -> Pram {
        Pram::with_mode(cells, max_pes, regs_per_pe, ExecMode::Audited)
    }

    /// Like [`Pram::new`] with an explicit execution tier.
    pub fn with_mode(cells: usize, max_pes: usize, regs_per_pe: usize, mode: ExecMode) -> Pram {
        Pram {
            mem: vec![0.0; cells],
            counters: Counters::default(),
            bank_model: BankModel::default(),
            strict: true,
            mode,
            fast_parallel_threshold: 4096,
            regs: vec![0.0; max_pes * regs_per_pe],
            regs_per_pe,
            writes_buf: Vec::new(),
            audit_log: XactLog::default(),
            scratch: AuditScratch::default(),
            worker_bufs: Vec::new(),
            hw_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }

    /// Cap the fast tier's per-step PE fan-out (the audited tier is
    /// serial by construction and ignores this).  Serving worker pools
    /// pass their per-worker thread share so that total transient
    /// concurrency across the pool stays at hardware width instead of
    /// workers × hardware threads.
    pub fn set_fast_threads(&mut self, n: usize) {
        self.hw_threads = n.max(1);
    }

    /// Run one synchronous step with PEs `0..pes`.
    ///
    /// Every PE executes `body(pe, ctx)`; reads observe pre-step memory;
    /// writes commit at the barrier.  Returns the CREW status (always Ok
    /// on the fast tier, which does not detect conflicts).
    pub fn step<F>(&mut self, pes: usize, body: F) -> Result<(), PramError>
    where
        F: Fn(usize, &mut PeCtx<'_>) + Sync,
    {
        match self.mode {
            ExecMode::Audited => self.step_audited(pes, body),
            ExecMode::Fast => {
                self.step_fast(pes, body);
                Ok(())
            }
        }
    }

    // ------------------------------------------------------------ audited

    fn step_audited<F>(&mut self, pes: usize, body: F) -> Result<(), PramError>
    where
        F: Fn(usize, &mut PeCtx<'_>),
    {
        self.writes_buf.clear();
        self.audit_log.reads.clear();
        self.audit_log.writes.clear();
        let rpp = self.regs_per_pe;
        for pe in 0..pes {
            let mut ctx = PeCtx {
                pe,
                mem: &self.mem,
                regs: &mut self.regs[pe * rpp..(pe + 1) * rpp],
                writes: &mut self.writes_buf,
                audit: Some(&mut self.audit_log),
            };
            body(pe, &mut ctx);
        }
        self.account(pes)
    }

    fn account(&mut self, pes: usize) -> Result<(), PramError> {
        self.scratch.ensure(self.mem.len());
        {
            let c = &mut self.counters;
            c.steps += 1;
            c.work += pes as u64;
            c.max_pes = c.max_pes.max(pes as u64);
            c.reads += self.audit_log.reads.len() as u64;
            c.writes += self.audit_log.writes.len() as u64;
            c.ideal_cycles += 1;
        }

        // ---- CREW write-conflict detection: sort-free, one pass in
        // program order over the epoch-stamped shadow array.
        let sc = &mut self.scratch;
        sc.step_epoch += 1;
        let ep = sc.step_epoch;
        for w in &self.writes_buf {
            if sc.write_stamp[w.addr] == ep {
                if sc.conflict_stamp[w.addr] != ep {
                    sc.conflict_stamp[w.addr] = ep;
                    self.counters.write_conflicts += 1;
                }
                if self.strict {
                    return Err(PramError {
                        step: self.counters.steps,
                        addr: w.addr,
                        pes: (sc.write_pe[w.addr] as usize, w.pe as usize),
                    });
                }
            } else {
                sc.write_stamp[w.addr] = ep;
                sc.write_pe[w.addr] = w.pe;
            }
        }

        // read-write overlap diagnostics (per read transaction)
        for r in &self.audit_log.reads {
            if sc.write_stamp[r.addr] == ep || (r.wide && sc.write_stamp[r.addr + 1] == ep) {
                self.counters.read_write_overlaps += 1;
            }
        }

        // ---- bank serialization model
        let cycles = Self::bank_cycles(self.bank_model, &self.audit_log, sc);
        self.counters.modeled_cycles += cycles;

        // commit writes (program order: PEs ran 0..pes serially, so the
        // last buffered write to a cell wins, deterministically)
        for w in &self.writes_buf {
            self.mem[w.addr] = w.val;
        }
        Ok(())
    }

    /// One step's modeled cycles: max over warps of (read serialization +
    /// write serialization), min 1.  Both logs are in PE-ascending order
    /// (serial dispatch), so warps form contiguous runs and a single
    /// merged pass with fixed `[u32; MAX_BANKS]` counters suffices — no
    /// maps, no sorting, no allocation.
    fn bank_cycles(bm: BankModel, log: &XactLog, sc: &mut AuditScratch) -> u64 {
        assert!(bm.banks <= MAX_BANKS, "bank model supports at most {MAX_BANKS} banks");
        let banks = bm.banks.max(1);
        let warp = bm.warp.max(1);
        let stride = bm.word_stride.max(1);
        let reads = &log.reads;
        let writes = &log.writes;
        let (mut i, mut j) = (0usize, 0usize);
        let mut step_cycles = 1u64;
        while i < reads.len() || j < writes.len() {
            let rw = if i < reads.len() { reads[i].pe as usize / warp } else { usize::MAX };
            let ww = if j < writes.len() { writes[j].pe as usize / warp } else { usize::MAX };
            let cur = rw.min(ww);

            let mut rcyc = 0u64;
            if rw == cur {
                sc.warp_epoch += 1;
                let ep = sc.warp_epoch;
                let mut cnt = [0u32; MAX_BANKS];
                while i < reads.len() && reads[i].pe as usize / warp == cur {
                    let x = reads[i];
                    i += 1;
                    // same-address accesses broadcast (CUDA): distinct
                    // (address, width) pairs count, once each
                    let key = (x.addr << 1) | x.wide as usize;
                    if sc.seen_stamp[key] != ep {
                        sc.seen_stamp[key] = ep;
                        let unit = if x.wide { 2 * stride } else { stride };
                        let bank = (x.addr / unit) % banks;
                        cnt[bank] += 1;
                        rcyc = rcyc.max(cnt[bank] as u64);
                    }
                }
            }

            let mut wcyc = 0u64;
            if ww == cur {
                sc.warp_epoch += 1;
                let ep = sc.warp_epoch;
                let mut cnt = [0u32; MAX_BANKS];
                while j < writes.len() && writes[j].pe as usize / warp == cur {
                    let x = writes[j];
                    j += 1;
                    let key = (x.addr << 1) | x.wide as usize;
                    if sc.seen_stamp[key] != ep {
                        sc.seen_stamp[key] = ep;
                        let unit = if x.wide { 2 * stride } else { stride };
                        let bank = (x.addr / unit) % banks;
                        cnt[bank] += 1;
                        wcyc = wcyc.max(cnt[bank] as u64);
                    }
                }
            }

            step_cycles = step_cycles.max(rcyc + wcyc);
        }
        step_cycles
    }

    // --------------------------------------------------------------- fast

    fn step_fast<F>(&mut self, pes: usize, body: F)
    where
        F: Fn(usize, &mut PeCtx<'_>) + Sync,
    {
        {
            let c = &mut self.counters;
            c.steps += 1;
            c.work += pes as u64;
            c.max_pes = c.max_pes.max(pes as u64);
            c.ideal_cycles += 1;
            c.modeled_cycles += 1; // modeled conflict-free
        }
        let rpp = self.regs_per_pe;
        let workers = Self::fast_workers(pes, self.fast_parallel_threshold, self.hw_threads);

        if workers <= 1 {
            self.writes_buf.clear();
            for pe in 0..pes {
                let mut ctx = PeCtx {
                    pe,
                    mem: &self.mem,
                    regs: &mut self.regs[pe * rpp..(pe + 1) * rpp],
                    writes: &mut self.writes_buf,
                    audit: None,
                };
                body(pe, &mut ctx);
            }
            for w in &self.writes_buf {
                self.mem[w.addr] = w.val;
            }
            return;
        }

        // parallel dispatch: contiguous PE ranges per worker, private
        // register windows, per-worker write buffers (reused step-to-step)
        let chunk = (pes + workers - 1) / workers;
        while self.worker_bufs.len() < workers {
            self.worker_bufs.push(Vec::new());
        }
        {
            let mem: &[f64] = &self.mem;
            let wbufs = &mut self.worker_bufs[..workers];
            let mut regs_rest: &mut [f64] = &mut self.regs;
            let mut consumed = 0usize;
            let body = &body;
            std::thread::scope(|scope| {
                for (w, wbuf) in wbufs.iter_mut().enumerate() {
                    let lo = w * chunk;
                    let hi = pes.min(lo + chunk);
                    wbuf.clear();
                    if lo >= hi {
                        continue;
                    }
                    let take = hi * rpp - consumed;
                    let (regs_chunk, rest) = std::mem::take(&mut regs_rest).split_at_mut(take);
                    consumed = hi * rpp;
                    regs_rest = rest;
                    scope.spawn(move || {
                        for pe in lo..hi {
                            let r0 = (pe - lo) * rpp;
                            let mut ctx = PeCtx {
                                pe,
                                mem,
                                regs: &mut regs_chunk[r0..r0 + rpp],
                                writes: &mut *wbuf,
                                audit: None,
                            };
                            body(pe, &mut ctx);
                        }
                    });
                }
            });
        }
        // barrier: merge in worker (= PE-ascending) order, so a conflicting
        // program resolves identically to the serial fast path
        for w in 0..workers {
            let buf = std::mem::take(&mut self.worker_bufs[w]);
            for cw in &buf {
                self.mem[cw.addr] = cw.val;
            }
            self.worker_bufs[w] = buf; // return the buffer (keep capacity)
        }
    }

    /// Worker-count policy: stay serial under the threshold, then give
    /// every worker at least half a threshold of PEs, capped by the
    /// machine's parallelism and [`MAX_FAST_WORKERS`].
    fn fast_workers(pes: usize, threshold: usize, hw: usize) -> usize {
        let threshold = threshold.max(2);
        if pes < threshold {
            return 1;
        }
        let by_load = (2 * pes / threshold).max(1);
        hw.min(by_load).min(MAX_FAST_WORKERS).max(1)
    }

    /// Convenience: reset counters (memory retained).
    pub fn reset_counters(&mut self) {
        self.counters = Counters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_commits_writes_at_barrier() {
        let mut m = Pram::new(4, 4, 0);
        m.mem[0] = 1.0;
        m.mem[1] = 2.0;
        // classic swap test: both PEs read old values
        m.step(2, |pe, ctx| {
            let v = ctx.read(1 - pe);
            ctx.write(pe, v);
        })
        .unwrap();
        assert_eq!(m.mem[0], 2.0);
        assert_eq!(m.mem[1], 1.0);
    }

    #[test]
    fn crew_violation_detected() {
        let mut m = Pram::new(2, 4, 0);
        let err = m
            .step(3, |_, ctx| ctx.write(0, 7.0))
            .unwrap_err();
        assert_eq!(err.addr, 0);
        assert_eq!(err.pes, (0, 1));
        assert_eq!(m.counters.write_conflicts, 1);
    }

    #[test]
    fn non_strict_counts_conflicts_once_per_cell() {
        let mut m = Pram::new(2, 4, 0);
        m.strict = false;
        m.step(3, |_, ctx| ctx.write(0, 7.0)).unwrap();
        // 3 writers to one cell = ONE conflicting cell this step
        assert_eq!(m.counters.write_conflicts, 1);
        // a second conflicting step counts again
        m.step(2, |_, ctx| ctx.write(1, 1.0)).unwrap();
        assert_eq!(m.counters.write_conflicts, 2);
    }

    #[test]
    fn conflicts_on_distinct_cells_count_separately() {
        let mut m = Pram::new(4, 8, 0);
        m.strict = false;
        m.step(4, |pe, ctx| ctx.write(pe / 2, pe as f64)).unwrap();
        assert_eq!(m.counters.write_conflicts, 2); // cells 0 and 1
    }

    #[test]
    fn exclusive_writes_pass() {
        let mut m = Pram::new(8, 8, 0);
        m.step(8, |pe, ctx| ctx.write(pe, pe as f64)).unwrap();
        assert_eq!(m.counters.write_conflicts, 0);
        assert_eq!(m.mem[5], 5.0);
    }

    #[test]
    fn work_and_steps_counted() {
        let mut m = Pram::new(8, 8, 0);
        m.step(8, |_, _| {}).unwrap();
        m.step(4, |_, _| {}).unwrap();
        assert_eq!(m.counters.steps, 2);
        assert_eq!(m.counters.work, 12);
        assert_eq!(m.counters.max_pes, 8);
    }

    #[test]
    fn bank_conflicts_modeled() {
        // 32 PEs all hitting bank 0 with distinct addresses: 32-way conflict
        let mut m = Pram::new(32 * 32, 32, 0);
        m.step(32, |pe, ctx| {
            let _ = ctx.read(pe * 32); // all map to bank 0
        })
        .unwrap();
        assert_eq!(m.counters.modeled_cycles, 32);
        assert_eq!(m.counters.ideal_cycles, 1);
        assert!((m.counters.conflict_factor() - 32.0).abs() < 1e-9);

        // stride-1 reads: conflict-free
        let mut m2 = Pram::new(32 * 32, 32, 0);
        m2.step(32, |pe, ctx| {
            let _ = ctx.read(pe);
        })
        .unwrap();
        assert_eq!(m2.counters.modeled_cycles, 1);
    }

    #[test]
    fn broadcast_reads_are_free() {
        // all PEs read the same cell: CUDA broadcast, 1 cycle
        let mut m = Pram::new(4, 32, 0);
        m.step(32, |_, ctx| {
            let _ = ctx.read(0);
        })
        .unwrap();
        assert_eq!(m.counters.modeled_cycles, 1);
    }

    #[test]
    fn pair_access_is_one_coalesced_transaction() {
        // 32 PEs each read the point at slot `pe` (cells 2pe, 2pe+1).
        // As scalar reads this would conflict (cells 2pe and 2pe+1 hit
        // even/odd banks twice per warp); as float2 transactions the bank
        // is (addr/2) % 32 = pe % 32 — conflict-free, like the paper's
        // vectorized loads.
        let mut m = Pram::new(64, 32, 0);
        m.step(32, |pe, ctx| {
            let _ = ctx.read_pair(2 * pe);
        })
        .unwrap();
        assert_eq!(m.counters.reads, 32); // one transaction per PE
        assert_eq!(m.counters.modeled_cycles, 1);

        // pair writes coalesce the same way
        let mut m2 = Pram::new(64, 32, 0);
        m2.step(32, |pe, ctx| ctx.write_pair(2 * pe, 1.0, 2.0)).unwrap();
        assert_eq!(m2.counters.writes, 32);
        assert_eq!(m2.counters.modeled_cycles, 1);
        assert_eq!(m2.counters.write_conflicts, 0);
        assert_eq!(m2.mem[63], 2.0);
    }

    #[test]
    fn strided_pair_access_conflicts() {
        // slot stride 32 => pair bank stride 0: full serialization
        let mut m = Pram::new(2 * 32 * 32, 32, 0);
        m.step(32, |pe, ctx| {
            let _ = ctx.read_pair(2 * (pe * 32));
        })
        .unwrap();
        assert_eq!(m.counters.modeled_cycles, 32);
    }

    #[test]
    fn read_write_overlap_is_benign_but_counted() {
        let mut m = Pram::new(2, 2, 0);
        m.mem[0] = 5.0;
        m.step(2, |pe, ctx| {
            if pe == 0 {
                let v = ctx.read(0);
                ctx.write(1, v);
            } else {
                ctx.write(0, 9.0);
            }
        })
        .unwrap();
        assert_eq!(m.mem[1], 5.0); // read saw pre-step value
        assert_eq!(m.mem[0], 9.0);
        assert_eq!(m.counters.read_write_overlaps, 1);
    }

    #[test]
    fn registers_are_private_and_persistent() {
        let mut m = Pram::new(1, 4, 2);
        m.step(4, |pe, ctx| ctx.set_reg(0, pe as f64 * 10.0)).unwrap();
        m.step(4, |pe, ctx| {
            assert_eq!(ctx.reg(0), pe as f64 * 10.0);
        })
        .unwrap();
        assert_eq!(m.counters.reads, 0); // registers don't touch shared mem
    }

    #[test]
    fn warps_cost_independently() {
        // warp 0 conflict-free, warp 1 has a 4-way conflict: step = 4 cycles
        let mut m = Pram::new(64 * 33, 64, 0);
        m.step(64, |pe, ctx| {
            if pe < 32 {
                let _ = ctx.read(pe);
            } else {
                let _ = ctx.read((pe % 4) * 32); // 4 distinct addrs, bank 0
            }
        })
        .unwrap();
        assert_eq!(m.counters.modeled_cycles, 4);
    }

    #[test]
    fn audited_counters_stable_across_repeated_steps() {
        // the shadow arrays must give identical answers on every step
        // (epoch discipline: no stale stamps leak between steps)
        let mut m = Pram::new(64, 32, 0);
        m.strict = false;
        for _ in 0..3 {
            m.step(32, |pe, ctx| {
                let _ = ctx.read(pe % 8); // 8 distinct cells, banks 0..7
                ctx.write(pe % 16, 1.0); // 16 cells, 2 writers each
            })
            .unwrap();
        }
        assert_eq!(m.counters.steps, 3);
        assert_eq!(m.counters.write_conflicts, 3 * 16);
        // per warp: reads 1 cycle (distinct banks), writes 1 cycle => 2
        assert_eq!(m.counters.modeled_cycles, 3 * 2);
    }

    // ------------------------------------------------------------- fast

    #[test]
    fn fast_tier_barrier_semantics_match() {
        let mut m = Pram::with_mode(4, 4, 0, ExecMode::Fast);
        m.mem[0] = 1.0;
        m.mem[1] = 2.0;
        m.step(2, |pe, ctx| {
            let v = ctx.read(1 - pe);
            ctx.write(pe, v);
        })
        .unwrap();
        assert_eq!(m.mem[0], 2.0);
        assert_eq!(m.mem[1], 1.0);
        assert_eq!(m.counters.steps, 1);
        assert_eq!(m.counters.work, 2);
        assert_eq!(m.counters.reads, 0); // fast tier logs nothing
    }

    #[test]
    fn fast_parallel_dispatch_matches_serial() {
        // same program on both dispatch paths; force parallel dispatch by
        // dropping the threshold to the minimum
        let n = 1024usize;
        let run = |threshold: usize| {
            let mut m = Pram::with_mode(n, n, 1, ExecMode::Fast);
            m.fast_parallel_threshold = threshold;
            for s in 0..4u64 {
                m.step(n, |pe, ctx| {
                    let v = ctx.read((pe + 1) % n);
                    ctx.set_reg(0, ctx.reg(0) + v);
                    ctx.write(pe, v + s as f64);
                })
                .unwrap();
            }
            (m.mem.clone(), m.counters.clone())
        };
        let (serial_mem, serial_c) = run(usize::MAX); // always serial
        let (par_mem, par_c) = run(2); // parallel whenever possible
        assert_eq!(serial_mem, par_mem);
        assert_eq!(serial_c, par_c);
    }

    #[test]
    fn fast_registers_persist_across_worker_layouts() {
        let mut m = Pram::with_mode(1, 256, 1, ExecMode::Fast);
        m.fast_parallel_threshold = 2;
        m.step(256, |pe, ctx| ctx.set_reg(0, pe as f64)).unwrap();
        // different pe count => different chunking; registers must still
        // map to the same absolute windows
        m.step(100, |pe, ctx| assert_eq!(ctx.reg(0), pe as f64)).unwrap();
    }

    #[test]
    fn fast_and_audited_agree_on_crew_clean_program() {
        let prog = |m: &mut Pram| {
            for _ in 0..5 {
                m.step(64, |pe, ctx| {
                    let (x, y) = ctx.read_pair(2 * ((pe + 3) % 64));
                    ctx.write_pair(2 * pe, y, x);
                })
                .unwrap();
            }
        };
        let mut a = Pram::new(128, 64, 0);
        for s in 0..128 {
            a.mem[s] = (s * 7 % 13) as f64;
        }
        let mut f = Pram::with_mode(128, 64, 0, ExecMode::Fast);
        f.mem.copy_from_slice(&a.mem);
        f.fast_parallel_threshold = 2;
        prog(&mut a);
        prog(&mut f);
        assert_eq!(a.mem, f.mem);
        assert_eq!(a.counters.steps, f.counters.steps);
        assert_eq!(a.counters.work, f.counters.work);
    }

    #[test]
    fn exec_mode_parse_roundtrip() {
        for m in [ExecMode::Audited, ExecMode::Fast] {
            assert_eq!(ExecMode::parse(m.name()), Some(m));
        }
        assert_eq!(ExecMode::parse("gpu"), None);
    }
}
