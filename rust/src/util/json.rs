//! Minimal JSON: parser + serializer (substitute for serde_json).
//!
//! Scope: everything this project reads (artifacts/manifest.json,
//! artifacts/report.json) and writes (metrics snapshots, experiment
//! reports).  Full RFC 8259 value grammar, string escapes incl. \uXXXX
//! (BMP only; surrogate pairs supported), f64 numbers.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects keep sorted key order (BTreeMap) so serialization
/// is deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup, None on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out, Some(0));
        out
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        write_json(self, &mut out, None);
        f.write_str(&out)
    }
}

fn write_indent(out: &mut String, level: usize) {
    out.push('\n');
    for _ in 0..level {
        out.push_str("  ");
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                    if indent.is_none() {
                        out.push(' ');
                    }
                }
                if let Some(l) = indent {
                    write_indent(out, l + 1);
                }
                write_json(item, out, indent.map(|l| l + 1));
            }
            if indent.is_some() && !items.is_empty() {
                write_indent(out, indent.unwrap());
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                    if indent.is_none() {
                        out.push(' ');
                    }
                }
                if let Some(l) = indent {
                    write_indent(out, l + 1);
                }
                write_escaped(k, out);
                out.push_str(": ");
                write_json(val, out, indent.map(|l| l + 1));
            }
            if indent.is_some() && !map.is_empty() {
                write_indent(out, indent.unwrap());
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate: require \uXXXX low surrogate
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // re-decode UTF-8 multibyte sequences from the source
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.pos = start + width;
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_i64(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "hull_n64_b1": {"file": "hull_n64_b1.hlo.txt", "kind": "hull",
                           "n": 64, "batch": 1, "outputs": 2,
                           "input_shape": [1, 64, 2]}
        }"#;
        let v = parse(src).unwrap();
        let e = v.get("hull_n64_b1").unwrap();
        assert_eq!(e.get("n").unwrap().as_usize(), Some(64));
        assert_eq!(e.get("outputs").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".to_string())
        );
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Json::Str("😀".to_string())
        );
        assert_eq!(parse("\"é😀\"").unwrap(), Json::Str("é😀".to_string()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "\"abc", "tru", "01x", "{\"a\" 1}", "[1]z"] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::obj(vec![
            ("nums", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
            ("s", Json::Str("a\"b".into())),
        ]);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
