//! Request/response types and input preprocessing.

use crate::geometry::point::{sort_by_x, Point};

/// A hull computation request (raw client points, any order).
#[derive(Clone, Debug)]
pub struct HullRequest {
    pub id: u64,
    pub points: Vec<Point>,
}

/// A completed hull: upper and lower chains, left-to-right, plus timings.
#[derive(Clone, Debug)]
pub struct HullResponse {
    pub id: u64,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
    /// which backend computed it ("pjrt", "native", "serial", ...).
    pub backend: &'static str,
    pub queue_ns: u64,
    pub exec_ns: u64,
}

/// Input rejection reasons.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestError {
    Empty,
    NonFinite(usize),
    OutOfRange(usize),
    TooLarge { points: usize, max: usize },
    Backend(String),
    Shutdown,
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Empty => write!(f, "empty point set"),
            RequestError::NonFinite(i) => write!(f, "point {i} is not finite"),
            RequestError::OutOfRange(i) => {
                write!(f, "point {i} outside [0,1]x[0,1] (normalize first)")
            }
            RequestError::TooLarge { points, max } => {
                write!(f, "{points} points exceeds the largest size class {max}")
            }
            RequestError::Backend(e) => write!(f, "backend failure: {e}"),
            RequestError::Shutdown => write!(f, "coordinator is shutting down"),
        }
    }
}

impl std::error::Error for RequestError {}

/// Preprocessed request ready for a Wagener backend.
#[derive(Clone, Debug)]
pub struct Prepared {
    pub id: u64,
    /// x-sorted, f32-quantized points.
    pub points: Vec<Point>,
    /// general position violated (duplicate x): needs the exact fallback.
    pub degenerate: bool,
}

/// Validate + canonicalize a request.
///
/// Points are quantized to f32 (the artifact wire type) and x-sorted; the
/// paper's coordinate convention ([0,1] x-range, REMOTE = x > 1) is
/// enforced here, and duplicate x-coordinates (general-position violation)
/// mark the request for the serial-exact path.
pub fn prepare(req: &HullRequest) -> Result<Prepared, RequestError> {
    if req.points.is_empty() {
        return Err(RequestError::Empty);
    }
    for (i, p) in req.points.iter().enumerate() {
        if !p.x.is_finite() || !p.y.is_finite() {
            return Err(RequestError::NonFinite(i));
        }
        if !(0.0..=1.0).contains(&p.x) || !(0.0..=1.0).contains(&p.y) {
            return Err(RequestError::OutOfRange(i));
        }
    }
    let mut pts: Vec<Point> = req.points.iter().map(|p| p.quantize_f32()).collect();
    sort_by_x(&mut pts);
    pts.dedup(); // exact duplicates can always be dropped
    let degenerate = pts.windows(2).any(|w| w[0].x == w[1].x);
    Ok(Prepared { id: req.id, points: pts, degenerate })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(v: &[(f64, f64)]) -> HullRequest {
        HullRequest {
            id: 1,
            points: v.iter().map(|&(x, y)| Point::new(x, y)).collect(),
        }
    }

    #[test]
    fn sorts_and_quantizes() {
        let p = prepare(&req(&[(0.9, 0.1), (0.1, 0.9)])).unwrap();
        assert!(p.points[0].x < p.points[1].x);
        assert!(!p.degenerate);
        for pt in &p.points {
            assert_eq!(pt.x, pt.x as f32 as f64);
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(matches!(prepare(&req(&[])), Err(RequestError::Empty)));
        assert!(matches!(
            prepare(&req(&[(f64::NAN, 0.0)])),
            Err(RequestError::NonFinite(0))
        ));
        assert!(matches!(
            prepare(&req(&[(0.5, 0.5), (1.5, 0.0)])),
            Err(RequestError::OutOfRange(1))
        ));
    }

    #[test]
    fn exact_duplicates_dropped() {
        let p = prepare(&req(&[(0.5, 0.5), (0.5, 0.5), (0.2, 0.2)])).unwrap();
        assert_eq!(p.points.len(), 2);
        assert!(!p.degenerate);
    }

    #[test]
    fn duplicate_x_flags_degenerate() {
        let p = prepare(&req(&[(0.5, 0.1), (0.5, 0.9), (0.2, 0.2)])).unwrap();
        assert_eq!(p.points.len(), 3);
        assert!(p.degenerate);
    }

    #[test]
    fn quantization_collision_detected() {
        // two doubles that collide in f32 become a duplicate and are merged
        let a = 0.1f64;
        let b = f64::from_bits(a.to_bits() + 1);
        let p = prepare(&req(&[(a, 0.3), (b, 0.3)])).unwrap();
        assert_eq!(p.points.len(), 1);
    }
}
