//! Wagener's PRAM upper-hull algorithm — the paper's core contribution.
//!
//! Three executions of the same algorithm live in this crate:
//!   * [`stage`]/[`merge`] — direct host implementation (fast native path,
//!     single source of truth for the phase semantics);
//!   * [`pram_exec`] — the same phases as explicit processor programs on
//!     the cost-accounting PRAM simulator (paper-faithful organisation,
//!     used for experiments E2/E4);
//!   * the Pallas kernel (`python/compile/kernels/wagener.py`) — executed
//!     from rust through PJRT artifacts.
//! All three are differentially tested against the serial oracle.

pub mod hull_merge;
pub mod merge;
pub mod occupancy;
pub mod pram_exec;
pub mod stage;
pub mod tangent;

pub use hull_merge::{merge_hulls, MergePath};
pub use stage::{full_hull, stage, stage_dims, upper_hood, upper_hull};
pub use tangent::Code;
