//! Thread-allocation / occupancy tables (paper Figure 2 — experiment E2).
//!
//! For each stage the paper launches n/2 threads in n/(2d) blocks of
//! d1 × d2.  This module reports that geometry plus how many lattice
//! threads actually had live sample points to work on (the cost of the
//! paper's padding-not-compression design decision).

use super::stage::{stage, stage_dims};
use crate::geometry::point::{pad_to_hood, Point};

/// One row of the Figure-2 table.
#[derive(Clone, Debug, PartialEq)]
pub struct OccupancyRow {
    pub stage: usize,
    pub d: usize,
    pub d1: usize,
    pub d2: usize,
    pub blocks: usize,
    pub threads: usize,
    /// threads whose P-sample index holds a live corner in mam1..3.
    pub active_threads: usize,
    /// live hood corners across all blocks before this stage's merge.
    pub live_corners: usize,
}

impl OccupancyRow {
    pub fn utilization(&self) -> f64 {
        self.active_threads as f64 / self.threads as f64
    }
}

/// Simulate the pipeline on the host and collect per-stage occupancy.
pub fn occupancy_table(points: &[Point], slots: usize) -> Vec<OccupancyRow> {
    let mut hood = pad_to_hood(points, slots);
    let mut rows = Vec::new();
    let mut d = 2usize;
    let mut stage_no = 1;
    while d < slots {
        let (d1, d2) = stage_dims(d);
        let blocks = slots / (2 * d);
        let mut active = 0usize;
        let mut live = 0usize;
        for blk in hood.chunks(2 * d) {
            live += blk.iter().filter(|p| p.is_live()).count();
            if blk[d].is_remote() {
                continue; // Q empty: whole block idles (padding passthrough)
            }
            // a lattice thread (x, y) is active in mam1..3 iff its sample
            // i_x = d2*x is live
            let p_live_samples = (0..d1).filter(|&x| blk[d2 * x].is_live()).count();
            active += p_live_samples * d2;
        }
        rows.push(OccupancyRow {
            stage: stage_no,
            d,
            d1,
            d2,
            blocks,
            threads: slots / 2,
            active_threads: active,
            live_corners: live,
        });
        hood = stage(&hood, d);
        d *= 2;
        stage_no += 1;
    }
    rows
}

/// Render the table in the paper's style.
pub fn format_table(rows: &[OccupancyRow]) -> String {
    let mut s = String::from(
        "stage      d   d1xd2   blocks  threads   active   util%  live-corners\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:>5} {:>6}  {:>3}x{:<3} {:>7} {:>8} {:>8} {:>7.1} {:>13}\n",
            r.stage,
            r.d,
            r.d1,
            r.d2,
            r.blocks,
            r.threads,
            r.active_threads,
            100.0 * r.utilization(),
            r.live_corners,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};

    #[test]
    fn geometry_matches_paper_launch() {
        let pts = generate(Distribution::UniformSquare, 1024, 1);
        let rows = occupancy_table(&pts, 1024);
        assert_eq!(rows.len(), 9); // log2(1024) - 1 stages
        for (k, r) in rows.iter().enumerate() {
            assert_eq!(r.d, 2 << k);
            assert_eq!(r.d1 * r.d2, r.d);
            assert_eq!(r.blocks * 2 * r.d, 1024);
            assert_eq!(r.threads, 512);
            assert!(r.active_threads <= r.threads);
        }
    }

    #[test]
    fn full_live_input_starts_fully_active() {
        let pts = generate(Distribution::Parabola, 64, 2);
        let rows = occupancy_table(&pts, 64);
        // stage 1: every 2-point block is fully live
        assert_eq!(rows[0].active_threads, rows[0].threads);
        // parabola: almost all points stay on the hull (the generator's
        // general-position jitter may shed a few) -> near-full activity
        for r in &rows {
            assert!(r.utilization() >= 0.85, "stage {}: {}", r.stage, r.utilization());
        }
    }

    #[test]
    fn valley_utilization_collapses() {
        let pts = generate(Distribution::Valley, 256, 2);
        let rows = occupancy_table(&pts, 256);
        let last = rows.last().unwrap();
        // hulls shrink to ~2 corners per block: most sample threads idle
        assert!(last.utilization() < 0.5, "util {}", last.utilization());
    }

    #[test]
    fn padding_blocks_idle() {
        let pts = generate(Distribution::UniformSquare, 16, 3);
        let rows = occupancy_table(&pts, 64); // 3/4 of slots are padding
        assert!(rows[0].active_threads <= 8);
    }

    #[test]
    fn table_renders() {
        let pts = generate(Distribution::Disk, 32, 4);
        let txt = format_table(&occupancy_table(&pts, 32));
        assert!(txt.contains("stage"));
        assert!(txt.lines().count() >= 4);
    }
}
