//! E14 — the accelerator-resident pipeline: octagon prefilter placement
//! (host vs device vs off) on dense uniform-in-disk inputs, and (E14b)
//! a merge-heavy streaming-session schedule with host vs device tangent
//! merges.
//!
//! Device rows need the pjrt backend with `filter_n*` / `tangent_n*`
//! artifacts compiled by `python -m python.compile.aot`; when the backend
//! cannot start (the vendored xla stub, or no artifacts) the device rows
//! are skipped with a note — the JSON trailer is still written, so
//! tier1's `assert_bench_written` gate holds everywhere.
//!
//! Run: `cargo bench --bench bench_accel` (tier1.sh feeds
//! BENCH_accel.json via WAGENER_BENCH_JSON).

use std::sync::Arc;

use wagener_hull::benchkit::{black_box, Bencher, Report};
use wagener_hull::coordinator::{
    BackendKind, Coordinator, CoordinatorConfig, PrefilterMode,
};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::stream::{SessionRegistry, StreamConfig};

fn coord(kind: BackendKind, prefilter: PrefilterMode) -> Result<Arc<Coordinator>, String> {
    Coordinator::start(CoordinatorConfig {
        backend: kind,
        prefilter,
        ..Default::default()
    })
    .map(Arc::new)
}

fn main() {
    let b = Bencher::default();

    // E14: one-shot hulls over dense disks — the prefilter's best case
    // (most points are strictly interior to the octagon).
    let mut report = Report::new("E14: prefilter placement (dense uniform-in-disk)");
    for exp in [16u32, 20] {
        let n = 1usize << exp;
        let pts = generate(Distribution::Disk, n, 14);
        for (mode, kind) in [
            (PrefilterMode::Off, BackendKind::Native),
            (PrefilterMode::Host, BackendKind::Native),
            (PrefilterMode::Device, BackendKind::Pjrt),
        ] {
            let c = match coord(kind, mode) {
                Ok(c) => c,
                Err(e) => {
                    report.note(format!(
                        "prefilter/{}_n{n}: skipped ({} backend unavailable: {e})",
                        mode.name(),
                        kind.name()
                    ));
                    continue;
                }
            };
            let pts2 = pts.clone();
            let c2 = c.clone();
            report.add(b.run(&format!("prefilter/{}_n{n}", mode.name()), move || {
                black_box(c2.compute(pts2.clone()).unwrap().upper.len())
            }));
            let snap = c.snapshot().0;
            report.note(format!(
                "{}_n{n}: points_in={} filtered_host={} filtered_device={} \
                 device_compaction={}",
                mode.name(),
                snap.get("points_in").unwrap(),
                snap.get("filtered_points_host").unwrap(),
                snap.get("filtered_points_device").unwrap(),
                snap.get("device_filter_compaction").unwrap(),
            ));
        }
    }
    report.finish();

    // E14b: merge-heavy session schedule — a low merge threshold keeps
    // the hull ⊕ hull combine (and, on pjrt, the tangent kernel's single
    // upload per merge) on the critical path.
    let mut report = Report::new("E14b: session merges, host vs device tangent");
    let n = 1usize << 16;
    let pts = generate(Distribution::Disk, n, 15);
    for (row, kind) in [("host_tangent", BackendKind::Native), ("device_tangent", BackendKind::Pjrt)]
    {
        let c = match coord(kind, PrefilterMode::Off) {
            Ok(c) => c,
            Err(e) => {
                report.note(format!(
                    "session/{row}: skipped ({} backend unavailable: {e})",
                    kind.name()
                ));
                continue;
            }
        };
        let registry = SessionRegistry::new(
            StreamConfig { merge_threshold: 1024, idle_ttl_ms: 0, ..Default::default() },
            c.metrics.clone(),
        );
        let pts2 = pts.clone();
        let c2 = c.clone();
        report.add(b.run(&format!("session/{row}_n{n}_batch1024"), move || {
            let sid = registry.open().unwrap();
            for chunk in pts2.chunks(1024) {
                registry.add(sid, chunk, &*c2).unwrap();
            }
            let snap = registry.hull(sid, &*c2).unwrap();
            registry.close(sid, &*c2).unwrap();
            black_box(snap.upper.len())
        }));
        let snap = c.snapshot().0;
        // round-trip accounting: every device tangent merge is exactly one
        // upload + one download by construction (the kernel takes the
        // padded [H(L) | H(R)] pair block in a single batch-2 program)
        report.note(format!(
            "{row}: merges_total={} device_tangent_merges={} (device path = 1 upload/merge)",
            snap.get("merges_total").unwrap(),
            snap.get("device_tangent_merges").unwrap(),
        ));
    }
    report.finish();
}
