//! The paper's §3 optimal-speedup variant, measured.
//!
//! Pipeline (paper sketch): split the x-sorted input into strips, compute
//! each strip's upper hull serially (O(strip) work each, O(n) total),
//! store the chains in balanced trees, then merge adjacent chains level by
//! level using the logarithmic tangent search — split/join instead of the
//! CUDA version's shift-copy, so merges move O(log) pointers, not O(d)
//! points.
//!
//! Experiment E5 compares this run's work counters against the standard
//! Wagener pipeline's Θ(n log n) (PRAM counters from wagener::pram_exec):
//! strip work ≈ n, tangent work ≈ (n / strip) · log²(strip hull), total
//! ≈ O(n) for strip = log²n — the paper's claim, now a measured number.

use super::tangent_search::{common_tangent, SearchCost};
use super::treap::Treap;
use crate::geometry::point::Point;
use crate::serial::monotone_chain;

/// Work counters for an optimal-variant run (E5's table row).
#[derive(Clone, Debug, Default)]
pub struct WorkStats {
    /// points scanned by the serial per-strip hulls (Θ(n)).
    pub strip_work: u64,
    /// number of strips / merge levels / merges performed.
    pub strips: usize,
    pub levels: usize,
    pub merges: u64,
    /// orientation tests spent in tangent searches (the parallel work).
    pub tangent_predicate_evals: u64,
    /// tree accesses during tangent searches.
    pub tangent_chain_accesses: u64,
    /// elements physically moved (split/join move none; reported to
    /// contrast with the CUDA pipeline's Θ(n log n) shift-copies).
    pub data_moves: u64,
}

impl WorkStats {
    /// total accounted work of this variant.
    pub fn total(&self) -> u64 {
        self.strip_work + self.tangent_predicate_evals + self.data_moves
    }
}

/// Result of an optimal-variant run.
#[derive(Debug)]
pub struct OptimalRun {
    pub hull: Vec<Point>,
    pub stats: WorkStats,
}

/// Paper's strip length for n points: log²(n), clamped to [4, n].
pub fn default_strip_len(n: usize) -> usize {
    let lg = (n.max(2) as f64).log2();
    ((lg * lg) as usize).clamp(4, n.max(4))
}

/// Upper hull via strip preprocessing + OvL merges.
///
/// `points` x-sorted distinct-x; `strip_len` 0 picks the paper's log²n.
pub fn optimal_upper_hull(points: &[Point], strip_len: usize) -> OptimalRun {
    let n = points.len();
    let mut stats = WorkStats::default();
    if n == 0 {
        return OptimalRun { hull: Vec::new(), stats };
    }
    let strip = if strip_len == 0 { default_strip_len(n) } else { strip_len.max(1) };

    // --- strip phase: serial hulls, one balanced tree per strip
    let mut chains: Vec<Treap> = Vec::with_capacity(n.div_ceil(strip));
    for (k, chunk) in points.chunks(strip).enumerate() {
        let hull = monotone_chain::upper_hull(chunk);
        stats.strip_work += chunk.len() as u64;
        chains.push(Treap::from_slice(&hull, 0x5741_6765 ^ k as u64));
    }
    stats.strips = chains.len();

    // --- merge phase: pairwise, level by level (the paper's passes)
    while chains.len() > 1 {
        stats.levels += 1;
        let mut next = Vec::with_capacity(chains.len().div_ceil(2));
        let mut iter = chains.into_iter();
        while let Some(left) = iter.next() {
            match iter.next() {
                None => next.push(left),
                Some(right) => {
                    let mut cost = SearchCost::default();
                    let (pi, qi) = common_tangent(&left, &right, &mut cost);
                    stats.tangent_predicate_evals += cost.predicate_evals;
                    stats.tangent_chain_accesses += cost.chain_accesses;
                    stats.merges += 1;
                    let (keep_l, _) = left.split_at(pi + 1);
                    let (_, keep_r) = right.split_at(qi);
                    next.push(keep_l.concat(keep_r));
                }
            }
        }
        chains = next;
    }

    let hull = chains.pop().map(|t| t.to_vec()).unwrap_or_default();
    stats.data_moves += hull.len() as u64; // the single final flatten
    OptimalRun { hull, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};

    #[test]
    fn matches_serial_all_distributions() {
        for dist in Distribution::ALL {
            for &n in &[1usize, 2, 7, 64, 257, 1000] {
                let pts = generate(dist, n, 23);
                let run = optimal_upper_hull(&pts, 0);
                assert_eq!(
                    run.hull,
                    monotone_chain::upper_hull(&pts),
                    "{} n={n}",
                    dist.name()
                );
            }
        }
    }

    #[test]
    fn strip_lengths_dont_matter_for_correctness() {
        let pts = generate(Distribution::Circle, 500, 31);
        let want = monotone_chain::upper_hull(&pts);
        for strip in [1usize, 2, 3, 16, 100, 500, 1000] {
            assert_eq!(optimal_upper_hull(&pts, strip).hull, want, "strip={strip}");
        }
    }

    #[test]
    fn strip_work_is_linear() {
        let pts = generate(Distribution::Parabola, 4096, 7);
        let run = optimal_upper_hull(&pts, 0);
        assert_eq!(run.stats.strip_work, 4096);
        assert_eq!(run.stats.strips, 4096usize.div_ceil(default_strip_len(4096)));
    }

    #[test]
    fn tangent_work_is_subquadratic_in_merge_sizes() {
        // worst case (all points on hull): tangent evals must stay far
        // below the Θ(n log n) of the standard pipeline
        let n = 4096;
        let pts = generate(Distribution::Parabola, n, 7);
        let run = optimal_upper_hull(&pts, 0);
        let nlogn = (n as f64 * (n as f64).log2()) as u64;
        assert!(
            run.stats.tangent_predicate_evals * 4 < nlogn,
            "evals {} vs n log n {}",
            run.stats.tangent_predicate_evals,
            nlogn
        );
    }

    #[test]
    fn default_strip_is_log_squared() {
        assert_eq!(default_strip_len(1024), 100);
        assert_eq!(default_strip_len(4), 4);
    }

    #[test]
    fn empty_input() {
        let run = optimal_upper_hull(&[], 0);
        assert!(run.hull.is_empty());
    }
}
