//! Network front-end: a line-oriented text protocol over TCP (the paper's
//! own file format extended with framing), a threaded server, and a
//! blocking client used by the examples, benches and integration tests.

pub mod client;
pub mod proto;
pub mod tcp;

pub use client::{HullClient, SessionAddReply, SessionHullReply};
pub use proto::{Request, Response, SessionVerb};
pub use tcp::{serve, serve_engine, serve_with_sessions, ServerConfig, ServerHandle};
