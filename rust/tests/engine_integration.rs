//! Engine-level acceptance gates: a sharded engine must be
//! indistinguishable from a 1-shard engine on results (bit-identical
//! hulls for one-shots AND sessions under randomized schedules), exact on
//! accounting (global `inserted == absorbed + pending + hull_points`),
//! and strictly sid-affine (a session's traffic never touches another
//! shard's registry).
//!
//! Reproduce any property failure with WAGENER_PROP_SEED=<seed>.

use std::sync::Arc;

use wagener_hull::coordinator::{BackendKind, CoordinatorConfig};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::{sort_by_x, Point};
use wagener_hull::prop_assert;
use wagener_hull::stream::StreamConfig;
use wagener_hull::util::property::check;
use wagener_hull::util::rng::Rng;

fn engine(shards: usize, merge_threshold: usize) -> Arc<Engine> {
    Arc::new(
        Engine::start(EngineConfig {
            shards,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Native,
                workers: 1, // 4 shards x 1 worker: cheap and deterministic
                ..Default::default()
            },
            stream: StreamConfig { merge_threshold, idle_ttl_ms: 0, ..Default::default() },
            ..Default::default()
        })
        .unwrap(),
    )
}

fn unique_vertices(upper: &[Point], lower: &[Point]) -> usize {
    let mut all: Vec<Point> = upper.iter().chain(lower.iter()).copied().collect();
    sort_by_x(&mut all);
    all.dedup();
    all.len()
}

/// THE shard-parity gate: one randomized schedule — interleaved one-shot
/// requests and session lifecycles over every generator distribution,
/// with duplicate re-feeds and random merge thresholds — replayed through
/// a 1-shard and a 4-shard engine, must produce bit-identical hulls,
/// epochs and absorbed/pending ledgers at every step, and the global
/// accounting invariant must be exact on both engines' merged metrics.
#[test]
fn prop_shard_parity_one_vs_four() {
    check("engine-shard-parity-1v4", 12, |rng: &mut Rng| {
        let threshold = rng.range_usize(1, 300);
        let e1 = engine(1, threshold);
        let e4 = engine(4, threshold);

        // one session per distribution in each engine; k-th opened here
        // corresponds to k-th opened there (sids differ: striping)
        let n_sessions = rng.range_usize(2, 6);
        let sids1: Vec<u64> = (0..n_sessions).map(|_| e1.session_open().unwrap()).collect();
        let sids4: Vec<u64> = (0..n_sessions).map(|_| e4.session_open().unwrap()).collect();
        let mut fed: Vec<Vec<Point>> = vec![Vec::new(); n_sessions];

        let steps = rng.range_usize(10, 30);
        for _ in 0..steps {
            let dist = Distribution::ALL[rng.range_usize(0, Distribution::ALL.len())];
            if rng.chance(0.35) {
                // interleaved one-shot: must be bit-identical across
                // engines no matter which shard the router picked
                let pts = generate(dist, rng.range_usize(1, 400), rng.next_u64());
                let a = e1.compute(pts.clone()).map_err(|e| e.to_string())?;
                let b = e4.compute(pts).map_err(|e| e.to_string())?;
                prop_assert!(a.upper == b.upper, "one-shot upper diverged");
                prop_assert!(a.lower == b.lower, "one-shot lower diverged");
            } else {
                let k = rng.range_usize(0, n_sessions);
                let chunk = if rng.chance(0.25) && !fed[k].is_empty() {
                    // duplicate re-feed: absorbed on both engines alike
                    let from = rng.range_usize(0, fed[k].len());
                    fed[k][from..].iter().copied().take(30).collect()
                } else {
                    generate(dist, rng.range_usize(1, 250), rng.next_u64())
                };
                let a = e1.session_add(sids1[k], &chunk).map_err(|e| e.to_string())?;
                let b = e4.session_add(sids4[k], &chunk).map_err(|e| e.to_string())?;
                prop_assert!(a == b, "session {k}: add outcome diverged: {a:?} vs {b:?}");
                fed[k].extend(chunk);
            }
        }

        // quiesce: flush every session and compare the authoritative hulls
        let mut hull_points = [0usize; 2];
        for k in 0..n_sessions {
            if fed[k].is_empty() {
                continue; // nothing inserted: SHULL on an empty session
                          // returns empty chains on both engines alike
            }
            let a = e1.session_hull(sids1[k]).map_err(|e| e.to_string())?;
            let b = e4.session_hull(sids4[k]).map_err(|e| e.to_string())?;
            prop_assert!(a.epoch == b.epoch, "session {k}: epoch diverged");
            prop_assert!(a.upper == b.upper, "session {k}: upper diverged");
            prop_assert!(a.lower == b.lower, "session {k}: lower diverged");
            hull_points[0] += unique_vertices(&a.upper, &a.lower);
            hull_points[1] += unique_vertices(&b.upper, &b.lower);
        }

        // exact global accounting on the MERGED metrics of each engine:
        // every point ever inserted is absorbed, pending, or a hull vertex
        let total_inserted: usize = fed.iter().map(Vec::len).sum();
        for (which, eng) in [(0usize, &e1), (1, &e4)] {
            let m = eng.snapshot().0;
            let absorbed = m.get("absorbed_points_total").unwrap().as_usize().unwrap();
            let pending = m.get("pending_points_total").unwrap().as_usize().unwrap();
            prop_assert!(pending == 0, "engine {which}: SHULL flushed everything");
            prop_assert!(
                absorbed + pending + hull_points[which] == total_inserted,
                "engine {which}: absorbed({absorbed}) + pending({pending}) + \
                 hull({}) != inserted({total_inserted})",
                hull_points[which]
            );
            prop_assert!(
                m.get("open_sessions").unwrap().as_usize() == Some(n_sessions),
                "engine {which}: open_sessions gauge"
            );
        }
        for k in 0..n_sessions {
            e1.session_close(sids1[k]).map_err(|e| e.to_string())?;
            e4.session_close(sids4[k]).map_err(|e| e.to_string())?;
        }
        Ok(())
    });
}

/// Sid-affinity: every `SADD` for a sid lands on the shard that allocated
/// it — the other three shards' registries and session gauges never move.
#[test]
fn sadds_for_one_sid_never_touch_another_shards_registry() {
    let e = engine(4, 1_000_000); // huge threshold: everything pends
    let sid = e.session_open().unwrap();
    let owner = ((sid - 1) % 4) as usize;
    let pts = generate(Distribution::Circle, 300, 9);
    for chunk in pts.chunks(50) {
        e.session_add(sid, chunk).unwrap();
    }
    for i in 0..4 {
        let frame = e.shard_coordinator(i).metrics.frame();
        if i == owner {
            assert_eq!(e.shard_registry(i).open_sessions(), 1);
            assert_eq!(frame.open_sessions, 1);
            assert!(frame.session_pending_points > 0, "circle points all pend");
        } else {
            assert_eq!(e.shard_registry(i).open_sessions(), 0, "shard {i} touched");
            assert_eq!(frame.open_sessions, 0, "shard {i} gauge moved");
            assert_eq!(frame.session_pending_points, 0, "shard {i} pending moved");
            assert_eq!(frame.session_absorbed_points, 0, "shard {i} absorbed moved");
        }
    }
    // ...and the merged aggregate still sees the whole session
    let m = e.snapshot().0;
    assert_eq!(m.get("open_sessions").unwrap().as_usize(), Some(1));
    assert_eq!(m.get("pending_points_total").unwrap().as_usize(), Some(300));
    e.session_close(sid).unwrap();
}

/// Unknown sids answer `unknown-session` from whatever shard the residue
/// routes to — exactly the standalone-registry behaviour.
#[test]
fn unknown_sids_answer_unknown_session_on_every_residue() {
    let e = engine(4, 64);
    for sid in [0u64, 1, 2, 3, 4, 999, u64::MAX] {
        let err = e.session_add(sid, &[Point::new(0.5, 0.5)]).unwrap_err();
        assert_eq!(err.to_string(), "unknown-session", "sid {sid}");
    }
}

/// A closed session's sid routes to the same shard forever: close, then
/// verify the tombstoned sid is unknown while a new session (necessarily
/// a different sid) works.
#[test]
fn closed_sids_stay_unknown_new_sessions_route_fresh() {
    let e = engine(4, 64);
    let sid = e.session_open().unwrap();
    e.session_add(sid, &[Point::new(0.25, 0.5)]).unwrap();
    e.session_close(sid).unwrap();
    assert_eq!(
        e.session_add(sid, &[Point::new(0.5, 0.5)]).unwrap_err().to_string(),
        "unknown-session"
    );
    let sid2 = e.session_open().unwrap();
    assert_ne!(sid, sid2);
    e.session_add(sid2, &[Point::new(0.5, 0.25)]).unwrap();
    e.session_close(sid2).unwrap();
}
