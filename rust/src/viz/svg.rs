//! `hood2ps` equivalent: render points, hull chains and (optionally) the
//! per-stage intermediate hoods to SVG — Figures 1 and 4 of the paper.

use std::fmt::Write as _;

use crate::geometry::point::Point;

/// Rendering options.
#[derive(Clone, Debug)]
pub struct SvgOptions {
    pub width: f64,
    pub height: f64,
    pub margin: f64,
    pub point_radius: f64,
    /// draw intermediate hoods (stage traces) in fading strokes.
    pub show_stages: bool,
}

impl Default for SvgOptions {
    fn default() -> Self {
        SvgOptions {
            width: 640.0,
            height: 640.0,
            margin: 20.0,
            point_radius: 2.0,
            show_stages: true,
        }
    }
}

fn map(p: Point, o: &SvgOptions) -> (f64, f64) {
    // input space [0,1]^2, y up -> svg y down
    (
        o.margin + p.x * (o.width - 2.0 * o.margin),
        o.height - o.margin - p.y * (o.height - 2.0 * o.margin),
    )
}

fn polyline(points: &[Point], o: &SvgOptions, style: &str, out: &mut String) {
    if points.len() < 2 {
        return;
    }
    out.push_str("<polyline fill=\"none\" ");
    out.push_str(style);
    out.push_str(" points=\"");
    for p in points {
        let (x, y) = map(*p, o);
        let _ = write!(out, "{x:.2},{y:.2} ");
    }
    out.push_str("\"/>\n");
}

/// Render a Figure-4-style picture: input points, final upper/lower hulls
/// and optional intermediate stage hoods.
pub fn render_hull_svg(
    points: &[Point],
    upper: &[Point],
    lower: &[Point],
    stages: &[Vec<Vec<Point>>],
    opts: &SvgOptions,
) -> String {
    let o = opts;
    let mut s = String::new();
    let _ = write!(
        s,
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{}\" height=\"{}\" \
         viewBox=\"0 0 {} {}\">\n",
        o.width, o.height, o.width, o.height
    );
    s.push_str("<rect width=\"100%\" height=\"100%\" fill=\"white\"/>\n");

    if o.show_stages {
        // earlier stages fainter, later stages stronger (Figure 1 feel)
        let n = stages.len().max(1);
        for (k, stage) in stages.iter().enumerate() {
            let alpha = 0.15 + 0.5 * (k as f64 / n as f64);
            let style = format!(
                "stroke=\"#4477aa\" stroke-width=\"1\" stroke-opacity=\"{alpha:.2}\""
            );
            for hood in stage {
                polyline(hood, o, &style, &mut s);
            }
        }
    }

    polyline(upper, o, "stroke=\"#cc3311\" stroke-width=\"2\"", &mut s);
    polyline(lower, o, "stroke=\"#117733\" stroke-width=\"2\"", &mut s);

    for p in points {
        let (x, y) = map(*p, o);
        let _ = write!(
            s,
            "<circle cx=\"{x:.2}\" cy=\"{y:.2}\" r=\"{}\" fill=\"black\"/>\n",
            o.point_radius
        );
    }
    for (chain, color) in [(upper, "#cc3311"), (lower, "#117733")] {
        for p in chain {
            let (x, y) = map(*p, o);
            let _ = write!(
                s,
                "<circle cx=\"{x:.2}\" cy=\"{y:.2}\" r=\"{}\" fill=\"{color}\"/>\n",
                o.point_radius + 1.5
            );
        }
    }
    s.push_str("</svg>\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::serial::monotone_chain;

    #[test]
    fn renders_well_formed_svg() {
        let pts = generate(Distribution::Disk, 64, 1);
        let (u, l) = monotone_chain::full_hull(&pts);
        let svg = render_hull_svg(&pts, &u, &l, &[], &SvgOptions::default());
        assert!(svg.starts_with("<svg"));
        assert!(svg.trim_end().ends_with("</svg>"));
        assert_eq!(svg.matches("<circle").count(), 64 + u.len() + l.len());
        assert_eq!(svg.matches("<polyline").count(), 2);
    }

    #[test]
    fn stage_hoods_rendered_when_enabled() {
        let pts = generate(Distribution::Circle, 16, 2);
        let (u, l) = monotone_chain::full_hull(&pts);
        let stages = vec![vec![pts[..8].to_vec(), pts[8..].to_vec()]];
        let with = render_hull_svg(&pts, &u, &l, &stages, &SvgOptions::default());
        let without = render_hull_svg(
            &pts,
            &u,
            &l,
            &stages,
            &SvgOptions { show_stages: false, ..Default::default() },
        );
        assert!(with.matches("<polyline").count() > without.matches("<polyline").count());
    }

    #[test]
    fn coordinates_mapped_into_canvas() {
        let pts = vec![Point::new(0.0, 0.0), Point::new(1.0, 1.0)];
        let svg = render_hull_svg(&pts, &pts, &pts, &[], &SvgOptions::default());
        assert!(svg.contains("cx=\"20.00\"")); // margin
        assert!(svg.contains("cy=\"20.00\""));
        assert!(svg.contains("cx=\"620.00\""));
    }
}
