//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Substitutes for the `rand` crate (unavailable offline).  All workload
//! generation, property tests and benches seed through this, so every
//! experiment in EXPERIMENTS.md is bit-reproducible.

/// SplitMix64 step — used to expand a 64-bit seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator (Blackman & Vigna). Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) (n > 0), Lemire-style rejection-free enough
    /// for test workloads (modulo bias negligible at our ranges).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // widening multiply avoids modulo bias almost entirely
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// true with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_reasonable() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
