//! The gateway's connection core.
//!
//! On unix this is the same readiness-driven shape as the TCP core
//! (`server/event_loop.rs`), sharing its `sys::Poller`/`sys::Waker`
//! plumbing and its watermark constants: a small pool of I/O loop
//! threads drives non-blocking sockets; complete HTTP requests bounce to
//! a bounded dispatch pool where the typed router runs the handler
//! (handlers may park — e.g. `POST /v1/hull` waits on the engine's reply
//! channel, exactly like the threaded TCP shim); the encoded response
//! posts back to the owning loop through its completion queue + waker.
//! A connection decodes one request at a time (`busy`), so pipelined
//! requests answer in order.  Malformed framing is fatal: the error
//! response flushes with `Connection: close` and the connection ends.
//!
//! Elsewhere (non-unix) a thread-per-connection fallback serves the same
//! routes over blocking sockets — same decoder, same router, same
//! metrics; only the concurrency shape differs.

use std::sync::Arc;

use crate::engine::Engine;

use super::{Ctx, GatewayConfig};

/// Handle to a running gateway (shutdown on drop).
pub struct GatewayHandle {
    inner: imp::Handle,
}

impl GatewayHandle {
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.inner.local_addr
    }

    /// Stop accepting, drain in-flight exchanges, and join every
    /// thread.  Dropping the handle does the same; this form just makes
    /// shutdown explicit at call sites.
    pub fn stop(self) {}
}

/// Start the HTTP gateway on `cfg.addr` (non-blocking; returns a
/// handle).  The engine's shared metrics sink gains (or reuses) its
/// `gateway` object, so TCP `STATS` starts reporting HTTP traffic the
/// moment this returns.
pub fn serve_gateway(engine: Arc<Engine>, cfg: &GatewayConfig) -> std::io::Result<GatewayHandle> {
    let metrics = engine.register_gateway_metrics();
    let ctx = Arc::new(Ctx {
        engine,
        metrics,
        request_timeout_ms: cfg.request_timeout_ms,
        page_limit: cfg.page_limit.max(1),
    });
    Ok(GatewayHandle { inner: imp::serve(ctx, cfg)? })
}

#[cfg(unix)]
mod imp {
    use std::collections::{HashMap, VecDeque};
    use std::io::{ErrorKind, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Arc, Condvar, Mutex};
    use std::thread::JoinHandle;
    use std::time::{Duration, Instant};

    use crate::coordinator::{GatewayRoute, Metrics};
    use crate::gateway::http::{self, HttpRequest};
    use crate::gateway::router::Router;
    use crate::gateway::{observe_exchange, Ctx, GatewayConfig};
    use crate::server::event_loop::{
        effective_io_threads, COMPACT_AT, DRAIN_MS, HIGH_WATER, LOW_WATER, READ_BUDGET, READ_CHUNK,
    };
    use crate::server::proto::Decoded;
    use crate::server::sys::{self, EV_READ, EV_WRITE};
    use crate::{log_debug, log_info};

    const TOKEN_LISTENER: u64 = 0;
    const TOKEN_WAKER: u64 = 1;
    const FIRST_CONN_TOKEN: u64 = 2;

    /// An encoded response ready for a connection's write buffer.
    struct Completion {
        token: u64,
        bytes: Vec<u8>,
        /// The request negotiated `Connection: close`: flush, then end.
        close_after: bool,
    }

    struct LoopShared {
        waker: sys::Waker,
        inbox: Mutex<Vec<TcpStream>>,
        completions: Mutex<Vec<Completion>>,
    }

    /// A decoded request bounced off the I/O thread to the dispatch pool.
    struct Job {
        shared: Arc<LoopShared>,
        token: u64,
        req: HttpRequest,
        /// Wire bytes the request consumed (for byte counters).
        bytes_in: u64,
        /// Stamped at frame arrival so pool queueing counts into latency.
        started: Instant,
    }

    struct PoolShared {
        jobs: Mutex<VecDeque<Job>>,
        cv: Condvar,
        stop: AtomicBool,
    }

    impl PoolShared {
        fn submit(&self, job: Job) {
            if let Ok(mut q) = self.jobs.lock() {
                q.push_back(job);
                self.cv.notify_one();
            }
        }
    }

    struct DispatchPool {
        shared: Arc<PoolShared>,
        threads: Vec<JoinHandle<()>>,
    }

    impl DispatchPool {
        fn start(ctx: Arc<Ctx>, workers: usize) -> std::io::Result<DispatchPool> {
            let shared = Arc::new(PoolShared {
                jobs: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                stop: AtomicBool::new(false),
            });
            let router = Arc::new(crate::gateway::build_router());
            let mut threads = Vec::with_capacity(workers);
            for i in 0..workers {
                let sh = shared.clone();
                let cx = ctx.clone();
                let rt = router.clone();
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("gw-dispatch-{i}"))
                        .spawn(move || run_worker(&cx, &rt, &sh))?,
                );
            }
            Ok(DispatchPool { shared, threads })
        }

        fn stop(self) {
            self.shared.stop.store(true, Ordering::SeqCst);
            self.shared.cv.notify_all();
            for t in self.threads {
                let _ = t.join();
            }
        }
    }

    fn run_worker(ctx: &Ctx, router: &Router<Ctx>, shared: &PoolShared) {
        loop {
            let job = {
                let Ok(mut q) = shared.jobs.lock() else { return };
                loop {
                    if let Some(j) = q.pop_front() {
                        break j;
                    }
                    if shared.stop.load(Ordering::SeqCst) {
                        return;
                    }
                    q = match shared.cv.wait(q) {
                        Ok(guard) => guard,
                        Err(_) => return,
                    };
                }
            };
            run_job(ctx, router, job);
        }
    }

    /// Route + run one request on a pool thread, record the exchange,
    /// post the encoded response back to the owning loop.
    fn run_job(ctx: &Ctx, router: &Router<Ctx>, job: Job) {
        let Job { shared, token, req, bytes_in, started } = job;
        let keep_alive = req.keep_alive;
        let d = router.dispatch(ctx, &req);
        let mut bytes = Vec::new();
        d.resp.encode(&mut bytes, keep_alive);
        observe_exchange(ctx, d.route, d.sid, d.resp.status, bytes_in, bytes.len() as u64, started);
        if let Ok(mut c) = shared.completions.lock() {
            c.push(Completion { token, bytes, close_after: !keep_alive });
        }
        shared.waker.wake();
    }

    /// Per-connection state machine — `Conn` from the TCP core minus
    /// protocol detection (there is only HTTP here) and error resync
    /// (framing errors are always fatal).
    struct Conn {
        stream: TcpStream,
        peer: String,
        rbuf: Vec<u8>,
        wbuf: Vec<u8>,
        woff: usize,
        interest: u32,
        registered: bool,
        busy: bool,
        paused: bool,
        closing: bool,
        read_closed: bool,
        requests: u64,
    }

    struct EventLoop {
        index: usize,
        poller: sys::Poller,
        conns: HashMap<u64, Conn>,
        shared: Arc<LoopShared>,
        peers: Vec<Arc<LoopShared>>,
        rr: usize,
        listener: Option<TcpListener>,
        ctx: Arc<Ctx>,
        pool: Arc<PoolShared>,
        stop: Arc<AtomicBool>,
        next_token: Arc<AtomicU64>,
        max_body_bytes: usize,
        draining: bool,
    }

    impl EventLoop {
        fn run(mut self) {
            let mut events: Vec<sys::Event> = Vec::new();
            let mut deadline: Option<Instant> = None;
            loop {
                if self.stop.load(Ordering::SeqCst) && !self.draining {
                    self.begin_drain();
                    deadline = Some(Instant::now() + Duration::from_millis(DRAIN_MS));
                }
                if self.draining {
                    if self.conns.is_empty() {
                        break;
                    }
                    if let Some(d) = deadline {
                        if Instant::now() >= d {
                            break;
                        }
                    }
                }
                let timeout = if self.draining { 25 } else { -1 };
                if let Err(e) = self.poller.wait(&mut events, timeout) {
                    log_info!("gw loop {}: poll error: {e}", self.index);
                    break;
                }
                for ev in events.iter().copied() {
                    match ev.token {
                        TOKEN_LISTENER => self.accept_burst(),
                        TOKEN_WAKER => self.shared.waker.drain(),
                        token => self.conn_event(token, ev),
                    }
                }
                self.apply_completions();
                if !self.draining {
                    self.adopt_inbox();
                }
            }
            let leftover: Vec<u64> = self.conns.keys().copied().collect();
            for token in leftover {
                self.close_conn(token);
            }
        }

        fn begin_drain(&mut self) {
            self.draining = true;
            if let Some(l) = self.listener.take() {
                let _ = self.poller.delete(l.as_raw_fd());
            }
            let tokens: Vec<u64> = self.conns.keys().copied().collect();
            for token in tokens {
                let settled = match self.conns.get(&token) {
                    Some(c) => !c.busy && c.woff == c.wbuf.len(),
                    None => continue,
                };
                if settled {
                    self.close_conn(token);
                } else {
                    self.update_interest(token);
                }
            }
        }

        fn accept_burst(&mut self) {
            loop {
                let accepted = match &self.listener {
                    Some(l) => l.accept(),
                    None => return,
                };
                match accepted {
                    Ok((stream, _)) => {
                        Metrics::inc(&self.ctx.metrics.accepted);
                        let idx = self.rr % self.peers.len();
                        self.rr = self.rr.wrapping_add(1);
                        if idx == self.index {
                            self.adopt(stream);
                        } else {
                            if let Ok(mut inbox) = self.peers[idx].inbox.lock() {
                                inbox.push(stream);
                            }
                            self.peers[idx].waker.wake();
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                    Err(e) => {
                        log_info!("gw accept error: {e}");
                        return;
                    }
                }
            }
        }

        fn adopt_inbox(&mut self) {
            let incoming: Vec<TcpStream> = match self.shared.inbox.lock() {
                Ok(mut inbox) => {
                    if inbox.is_empty() {
                        return;
                    }
                    inbox.drain(..).collect()
                }
                Err(_) => return,
            };
            for stream in incoming {
                self.adopt(stream);
            }
        }

        fn adopt(&mut self, stream: TcpStream) {
            let _ = stream.set_nodelay(true);
            if stream.set_nonblocking(true).is_err() {
                return;
            }
            let token = self.next_token.fetch_add(1, Ordering::Relaxed);
            if self.poller.add(stream.as_raw_fd(), token, EV_READ).is_err() {
                return;
            }
            let peer = match stream.peer_addr() {
                Ok(p) => p.to_string(),
                Err(_) => "<unknown>".into(),
            };
            log_debug!("gw conn {peer}: connected (loop {})", self.index);
            Metrics::inc(&self.ctx.metrics.open_connections);
            self.conns.insert(
                token,
                Conn {
                    stream,
                    peer,
                    rbuf: Vec::new(),
                    wbuf: Vec::new(),
                    woff: 0,
                    interest: EV_READ,
                    registered: true,
                    busy: false,
                    paused: false,
                    closing: false,
                    read_closed: false,
                    requests: 0,
                },
            );
        }

        fn close_conn(&mut self, token: u64) {
            if let Some(conn) = self.conns.remove(&token) {
                if conn.registered {
                    let _ = self.poller.delete(conn.stream.as_raw_fd());
                }
                Metrics::sub(&self.ctx.metrics.open_connections, 1);
                log_debug!(
                    "gw conn {}: disconnected after {} request(s) (loop {})",
                    conn.peer,
                    conn.requests,
                    self.index
                );
            }
        }

        fn conn_event(&mut self, token: u64, ev: sys::Event) {
            let Some(conn) = self.conns.get(&token) else {
                return; // stale event for a connection closed this iteration
            };
            let skip_read = conn.read_closed || self.draining;
            if ev.writable && !self.flush_conn(token) {
                self.close_conn(token);
                return;
            }
            if ev.readable && !skip_read && !self.read_conn(token) {
                self.close_conn(token);
                return;
            }
            self.post_io(token);
        }

        fn post_io(&mut self, token: u64) {
            self.decode_conn(token);
            if !self.flush_conn(token) {
                self.close_conn(token);
                return;
            }
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.read_closed && !conn.busy {
                conn.closing = true;
            }
            if conn.closing && !conn.busy && conn.woff == conn.wbuf.len() {
                self.close_conn(token);
                return;
            }
            self.update_interest(token);
        }

        fn read_conn(&mut self, token: u64) -> bool {
            let Some(conn) = self.conns.get_mut(&token) else { return true };
            let mut chunk = [0u8; READ_CHUNK];
            let budget = conn.rbuf.len() + READ_BUDGET;
            loop {
                match conn.stream.read(&mut chunk) {
                    Ok(0) => {
                        conn.read_closed = true;
                        return true;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&chunk[..n]);
                        if n < chunk.len() || conn.rbuf.len() >= budget {
                            return true;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => return false,
                }
            }
        }

        /// Decode at most one request out of the read buffer: a complete
        /// request dispatches and pauses the connection (`busy`) until
        /// its completion returns, so pipelined requests answer in
        /// order; broken framing ends the connection.
        fn decode_conn(&mut self, token: u64) {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.busy || conn.closing || conn.rbuf.is_empty() {
                return;
            }
            match http::decode_request(&conn.rbuf, self.max_body_bytes) {
                Ok(Decoded::Need(_)) => {}
                Ok(Decoded::Frame(req, used)) => {
                    conn.rbuf.drain(..used);
                    conn.requests += 1;
                    conn.busy = true;
                    self.pool.submit(Job {
                        shared: self.shared.clone(),
                        token,
                        req,
                        bytes_in: used as u64,
                        started: Instant::now(),
                    });
                }
                Err(e) => {
                    // framing can no longer be trusted: answer with
                    // Connection: close and tear the connection down
                    let resp = http::HttpResponse::error(e.status(), e.code(), &e.to_string());
                    let mut bytes = Vec::new();
                    resp.encode(&mut bytes, false);
                    let bytes_in = conn.rbuf.len() as u64;
                    conn.rbuf.clear();
                    conn.wbuf.extend_from_slice(&bytes);
                    conn.closing = true;
                    log_info!("gw conn {}: {e}", conn.peer);
                    Metrics::inc(&self.ctx.metrics.decode_errors);
                    observe_exchange(
                        &self.ctx,
                        GatewayRoute::Other,
                        None,
                        resp.status,
                        bytes_in,
                        bytes.len() as u64,
                        Instant::now(),
                    );
                }
            }
        }

        fn flush_conn(&mut self, token: u64) -> bool {
            let Some(conn) = self.conns.get_mut(&token) else { return true };
            while conn.woff < conn.wbuf.len() {
                match conn.stream.write(&conn.wbuf[conn.woff..]) {
                    Ok(0) => return false,
                    Ok(n) => conn.woff += n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => return false,
                }
            }
            if conn.woff == conn.wbuf.len() {
                conn.wbuf.clear();
                conn.woff = 0;
            } else if conn.woff >= COMPACT_AT {
                conn.wbuf.drain(..conn.woff);
                conn.woff = 0;
            }
            if conn.paused && conn.wbuf.len() - conn.woff < LOW_WATER {
                conn.paused = false;
            }
            true
        }

        fn apply_completions(&mut self) {
            let done: Vec<Completion> = match self.shared.completions.lock() {
                Ok(mut c) => {
                    if c.is_empty() {
                        return;
                    }
                    c.drain(..).collect()
                }
                Err(_) => return,
            };
            for c in done {
                let Some(conn) = self.conns.get_mut(&c.token) else {
                    continue; // connection died while its request ran
                };
                conn.busy = false;
                conn.wbuf.extend_from_slice(&c.bytes);
                if c.close_after {
                    conn.closing = true;
                }
                if !conn.paused && conn.wbuf.len() - conn.woff >= HIGH_WATER {
                    conn.paused = true;
                }
                self.post_io(c.token);
            }
        }

        fn update_interest(&mut self, token: u64) {
            let draining = self.draining;
            let Some(conn) = self.conns.get_mut(&token) else { return };
            let mut want = 0u32;
            if !conn.closing && !conn.busy && !conn.paused && !conn.read_closed && !draining {
                want |= EV_READ;
            }
            if conn.woff < conn.wbuf.len() {
                want |= EV_WRITE;
            }
            let fd = conn.stream.as_raw_fd();
            if want == 0 {
                if conn.registered {
                    let _ = self.poller.delete(fd);
                    conn.registered = false;
                }
            } else if !conn.registered {
                if self.poller.add(fd, token, want).is_ok() {
                    conn.registered = true;
                    conn.interest = want;
                }
            } else if want != conn.interest && self.poller.modify(fd, token, want).is_ok() {
                conn.interest = want;
            }
        }
    }

    pub(super) struct Handle {
        pub(super) local_addr: std::net::SocketAddr,
        stop: Arc<AtomicBool>,
        loops: Vec<Arc<LoopShared>>,
        threads: Vec<JoinHandle<()>>,
        pool: Option<DispatchPool>,
    }

    impl Handle {
        fn stop_inner(&mut self) {
            self.stop.store(true, Ordering::SeqCst);
            for shared in &self.loops {
                shared.waker.wake();
            }
            for t in self.threads.drain(..) {
                let _ = t.join();
            }
            if let Some(pool) = self.pool.take() {
                pool.stop();
            }
        }
    }

    impl Drop for Handle {
        fn drop(&mut self) {
            self.stop_inner();
        }
    }

    pub(super) fn serve(ctx: Arc<Ctx>, cfg: &GatewayConfig) -> std::io::Result<Handle> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        sys::raise_nofile_limit(1 << 16);

        let io_threads = effective_io_threads(cfg.io_threads);
        let stop = Arc::new(AtomicBool::new(false));
        let next_token = Arc::new(AtomicU64::new(FIRST_CONN_TOKEN));
        log_info!(
            "gateway on {local_addr} (backend={} shards={} io_threads={io_threads})",
            ctx.engine.backend_name(),
            ctx.engine.shard_count()
        );

        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let pool = DispatchPool::start(ctx.clone(), hw.clamp(4, 16))?;

        let mut shareds = Vec::with_capacity(io_threads);
        for _ in 0..io_threads {
            shareds.push(Arc::new(LoopShared {
                waker: sys::Waker::new()?,
                inbox: Mutex::new(Vec::new()),
                completions: Mutex::new(Vec::new()),
            }));
        }

        let mut listener = Some(listener);
        let mut threads = Vec::with_capacity(io_threads);
        for (i, shared) in shareds.iter().enumerate() {
            let mut poller = sys::Poller::new()?;
            poller.add(shared.waker.fd(), TOKEN_WAKER, EV_READ)?;
            let own_listener = if i == 0 {
                let l = listener.take().expect("loop 0 takes the listener");
                poller.add(l.as_raw_fd(), TOKEN_LISTENER, EV_READ)?;
                Some(l)
            } else {
                None
            };
            let lp = EventLoop {
                index: i,
                poller,
                conns: HashMap::new(),
                shared: shared.clone(),
                peers: shareds.clone(),
                rr: i,
                listener: own_listener,
                ctx: ctx.clone(),
                pool: pool.shared.clone(),
                stop: stop.clone(),
                next_token: next_token.clone(),
                max_body_bytes: cfg.max_body_bytes.max(1),
                draining: false,
            };
            threads.push(
                std::thread::Builder::new().name(format!("gw-io-{i}")).spawn(move || lp.run())?,
            );
        }

        Ok(Handle { local_addr, stop, loops: shareds, threads, pool: Some(pool) })
    }
}

#[cfg(not(unix))]
mod imp {
    use std::io::{ErrorKind, Read, Write};
    use std::net::{Shutdown, TcpListener, TcpStream};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::thread::JoinHandle;
    use std::time::Instant;

    use crate::coordinator::{GatewayRoute, Metrics};
    use crate::gateway::http;
    use crate::gateway::router::Router;
    use crate::gateway::{observe_exchange, Ctx, GatewayConfig};
    use crate::server::proto::Decoded;
    use crate::{log_debug, log_info};

    /// Thread-per-connection fallback: blocking sockets, the same
    /// incremental decoder fed from a loop, the same router.
    pub(super) struct Handle {
        pub(super) local_addr: std::net::SocketAddr,
        stop: Arc<AtomicBool>,
        conns: Arc<Mutex<Vec<TcpStream>>>,
        accept_thread: Option<JoinHandle<()>>,
    }

    impl Handle {
        fn stop_inner(&mut self) {
            self.stop.store(true, Ordering::SeqCst);
            // poke the blocking accept loop awake
            let _ = TcpStream::connect(self.local_addr);
            if let Ok(conns) = self.conns.lock() {
                for c in conns.iter() {
                    let _ = c.shutdown(Shutdown::Both);
                }
            }
            if let Some(t) = self.accept_thread.take() {
                let _ = t.join();
            }
        }
    }

    impl Drop for Handle {
        fn drop(&mut self) {
            self.stop_inner();
        }
    }

    fn serve_conn(ctx: &Ctx, router: &Router<Ctx>, mut stream: TcpStream, max_body: usize) {
        let _ = stream.set_nodelay(true);
        let mut rbuf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 16 * 1024];
        loop {
            let (req, used) = loop {
                match http::decode_request(&rbuf, max_body) {
                    Ok(Decoded::Frame(req, used)) => break (req, used),
                    Ok(Decoded::Need(_)) => match stream.read(&mut chunk) {
                        Ok(0) => return,
                        Ok(n) => rbuf.extend_from_slice(&chunk[..n]),
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => return,
                    },
                    Err(e) => {
                        let resp = http::HttpResponse::error(e.status(), e.code(), &e.to_string());
                        let mut bytes = Vec::new();
                        resp.encode(&mut bytes, false);
                        Metrics::inc(&ctx.metrics.decode_errors);
                        observe_exchange(
                            ctx,
                            GatewayRoute::Other,
                            None,
                            resp.status,
                            rbuf.len() as u64,
                            bytes.len() as u64,
                            Instant::now(),
                        );
                        let _ = stream.write_all(&bytes);
                        return;
                    }
                }
            };
            rbuf.drain(..used);
            let started = Instant::now();
            let keep_alive = req.keep_alive;
            let d = router.dispatch(ctx, &req);
            let mut bytes = Vec::new();
            d.resp.encode(&mut bytes, keep_alive);
            observe_exchange(ctx, d.route, d.sid, d.resp.status, used as u64, bytes.len() as u64, started);
            if stream.write_all(&bytes).is_err() || !keep_alive {
                return;
            }
        }
    }

    pub(super) fn serve(ctx: Arc<Ctx>, cfg: &GatewayConfig) -> std::io::Result<Handle> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let router = Arc::new(crate::gateway::build_router());
        let max_body = cfg.max_body_bytes.max(1);
        log_info!(
            "gateway on {local_addr} (backend={} shards={} core=threaded)",
            ctx.engine.backend_name(),
            ctx.engine.shard_count()
        );
        let accept_thread = {
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::Builder::new().name("gw-accept".into()).spawn(move || {
                for accepted in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    let stream = match accepted {
                        Ok(s) => s,
                        Err(e) => {
                            log_info!("gw accept error: {e}");
                            continue;
                        }
                    };
                    Metrics::inc(&ctx.metrics.accepted);
                    Metrics::inc(&ctx.metrics.open_connections);
                    if let (Ok(mut registry), Ok(clone)) = (conns.lock(), stream.try_clone()) {
                        registry.push(clone);
                    }
                    let cx = ctx.clone();
                    let rt = router.clone();
                    let spawned = std::thread::Builder::new().name("gw-conn".into()).spawn(
                        move || {
                            serve_conn(&cx, &rt, stream, max_body);
                            Metrics::sub(&cx.metrics.open_connections, 1);
                            log_debug!("gw conn closed");
                        },
                    );
                    if let Err(e) = spawned {
                        log_info!("gw spawn error: {e}");
                    }
                }
            })?
        };
        Ok(Handle { local_addr, stop, conns, accept_thread: Some(accept_thread) })
    }
}
