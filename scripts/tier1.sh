#!/usr/bin/env bash
# Tier-1 gate + perf baseline.
#
#   scripts/tier1.sh            # build, test, smoke-bench
#
# Runs `cargo build --release && cargo test -q` (the ROADMAP tier-1
# verify) and then a fast smoke run of bench_runtime with
# WAGENER_BENCH_JSON pointed at BENCH_pram.json, so every PR leaves a
# machine-readable perf record (PRAM audited-vs-fast tier timings) for
# the next PR to compare against.
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH; install a Rust toolchain" >&2
    exit 1
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

echo "== tier1: smoke bench -> BENCH_pram.json =="
: > "$ROOT/BENCH_pram.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_pram.json" \
    cargo bench --bench bench_runtime

echo "tier1 OK — bench rows in BENCH_pram.json:"
cat "$ROOT/BENCH_pram.json"
