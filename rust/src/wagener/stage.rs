//! Stage pipeline: the paper's main loop (`while (d < count)`), host path.

use super::merge;
use super::merge::merge_block_d;
use crate::geometry::point::{live_prefix, pad_to_hood, Point};

/// The paper's thread-block shape for hood size d = 2^r:
/// d1 = 2^⌈r/2⌉, d2 = 2^⌊r/2⌋ (so d1·d2 = d and d2 ≤ d1 ≤ 2·d2).
pub fn stage_dims(d: usize) -> (usize, usize) {
    assert!(d.is_power_of_two() && d >= 2, "d must be a power of two >= 2, got {d}");
    let r = d.trailing_zeros() as usize;
    (1 << ((r + 1) / 2), 1 << (r / 2))
}

/// One merge stage into a caller-provided buffer (hot path, §Perf P1).
pub fn stage_into(hood: &[Point], d: usize, out: &mut [Point]) {
    assert_eq!(hood.len() % (2 * d), 0, "n={} d={d}", hood.len());
    assert_eq!(out.len(), hood.len());
    let (d1, d2) = stage_dims(d);
    for (blk, out_blk) in hood.chunks(2 * d).zip(out.chunks_mut(2 * d)) {
        merge::merge_block_into(blk, d1, d2, out_blk);
    }
}

/// One merge stage: hoods of size d -> hoods of size 2d over the whole
/// hood array (the body of the paper's kernel-launch loop).
pub fn stage(hood: &[Point], d: usize) -> Vec<Point> {
    let mut out = vec![crate::geometry::point::REMOTE; hood.len()];
    stage_into(hood, d, &mut out);
    out
}

/// Full pipeline: upper hood of x-sorted, distinct-x points as an n-slot
/// block (n = `slots`, a power of two >= points.len()).
/// Ping-pongs two buffers — no allocation inside the stage loop.
pub fn upper_hood(points: &[Point], slots: usize) -> Vec<Point> {
    let mut cur = pad_to_hood(points, slots);
    let mut buf = vec![crate::geometry::point::REMOTE; slots];
    let mut d = 2;
    while d < slots {
        stage_into(&cur, d, &mut buf);
        std::mem::swap(&mut cur, &mut buf);
        d *= 2;
    }
    cur
}

/// Upper hull corners (live prefix of the final hood).
pub fn upper_hull(points: &[Point]) -> Vec<Point> {
    if points.len() <= 2 {
        return points.to_vec();
    }
    let slots = points.len().next_power_of_two();
    live_prefix(&upper_hood(points, slots)).to_vec()
}

/// Full hull (upper, lower) via the y-negation trick used by L2.
pub fn full_hull(points: &[Point]) -> (Vec<Point>, Vec<Point>) {
    let upper = upper_hull(points);
    let neg: Vec<Point> = points.iter().map(|p| Point::new(p.x, -p.y)).collect();
    let lower = upper_hull(&neg)
        .into_iter()
        .map(|p| Point::new(p.x, -p.y))
        .collect();
    (upper, lower)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::geometry::hull_check::check_upper_hull;
    use crate::serial::hood::{check_block_invariant, oracle_stage};
    use crate::serial::monotone_chain;

    #[test]
    fn stage_dims_match_paper_schedule() {
        // paper: d1=2,d2=1 then alternate doubling -> (2,2),(4,2),(4,4)...
        let (mut d1, mut d2) = (2usize, 1usize);
        let mut d = 2usize;
        while d <= 1 << 16 {
            assert_eq!(stage_dims(d), (d1, d2), "d={d}");
            if d1 > d2 {
                d2 *= 2;
            } else {
                d1 *= 2;
            }
            d *= 2;
        }
    }

    #[test]
    fn pipeline_matches_serial_on_all_distributions() {
        for dist in Distribution::ALL {
            for seed in 0..4 {
                for &n in &[4usize, 16, 64, 256] {
                    let pts = generate(dist, n, seed);
                    let got = upper_hull(&pts);
                    let want = monotone_chain::upper_hull(&pts);
                    assert_eq!(got, want, "{} n={n} seed={seed}", dist.name());
                    check_upper_hull(&pts, &got).unwrap();
                }
            }
        }
    }

    #[test]
    fn every_stage_matches_oracle_and_invariant() {
        let pts = generate(Distribution::Disk, 128, 77);
        let mut hood = pad_to_hood(&pts, 128);
        let mut d = 2;
        while d < 128 {
            let got = stage(&hood, d);
            let want = oracle_stage(&hood, d);
            assert_eq!(got, want, "d={d}");
            check_block_invariant(&got, 2 * d).unwrap();
            hood = got;
            d *= 2;
        }
    }

    #[test]
    fn padded_input_any_m() {
        for m in [1usize, 2, 3, 5, 31, 33, 64, 100] {
            let pts = generate(Distribution::UniformSquare, m, 5);
            let slots = m.next_power_of_two().max(2);
            let hood = upper_hood(&pts, slots);
            let want = monotone_chain::upper_hull(&pts);
            assert_eq!(live_prefix(&hood), &want[..], "m={m}");
        }
    }

    #[test]
    fn full_hull_matches_serial() {
        let pts = generate(Distribution::Circle, 256, 8);
        let (u, l) = full_hull(&pts);
        let (su, sl) = monotone_chain::full_hull(&pts);
        assert_eq!(u, su);
        assert_eq!(l, sl);
    }

    #[test]
    fn oversize_slots_ok() {
        // m much smaller than slots: whole Q subtrees are REMOTE
        let pts = generate(Distribution::Bimodal, 5, 1);
        let hood = upper_hood(&pts, 64);
        assert_eq!(
            live_prefix(&hood),
            &monotone_chain::upper_hull(&pts)[..]
        );
    }
}
