//! HTTP/JSON edge gateway.
//!
//! A dependency-free HTTP/1.1 server ([`http`]) riding the same
//! readiness-driven event-loop design as the TCP core
//! (`server/event_loop.rs`): non-blocking sockets, level-triggered
//! polling, keep-alive, and the identical high/low-watermark
//! backpressure constants.  A typed routing layer ([`router`]) maps
//! method + path patterns onto handlers with typed path/query
//! extraction; handlers front the *same* [`Engine`] the TCP listener
//! serves, making the identical engine calls as the shared response
//! builders in `server::mod` — so every HTTP exchange is bit-identical
//! in substance to its TCP equivalent (the parity suite pins this).
//!
//! Routes:
//!
//! | route                                | engine call |
//! |--------------------------------------|-------------|
//! | `POST /v1/hull`                      | [`Engine::submit`] (JSON or raw LE-f64 body) |
//! | `POST /v1/sessions`                  | [`Engine::session_open`] / [`Engine::session_restore`] |
//! | `POST /v1/sessions/{sid}/points`     | [`Engine::session_add_deadline`] |
//! | `GET /v1/sessions/{sid}/hull`        | [`Engine::session_hull_at`] (+ cursor pagination) |
//! | `DELETE /v1/sessions/{sid}`          | [`Engine::session_close`] |
//! | `GET /v1/stats`                      | [`Engine::stats`] |
//! | `GET /healthz`, `GET /readyz`        | liveness / readiness |
//!
//! Hull reads paginate through opaque cursors ([`cursor`]): the cursor
//! pins the epoch, so pages reassemble bit-identically to a one-shot
//! `SHULL` no matter what lands in between.  Typed engine errors map to
//! stable statuses through `crate::errors`; every response carries the
//! uniform `{"error":{"code","message"}}` body on failure.  Per-route
//! counters and latency histograms live in the engine's shared metrics
//! sink ([`GatewayMetrics`]) and surface in both TCP `STATS` and
//! `GET /v1/stats`.

pub mod client;
pub mod cursor;
pub mod http;
pub mod router;
mod server;

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{GatewayMetrics, GatewayRoute, HullRequest};
use crate::engine::Engine;
use crate::errors;
use crate::geometry::point::Point;
use crate::server::proto::MAX_REQUEST_POINTS;
use crate::server::{frame, request_deadline};
use crate::util::json::{self, Json};

use http::{HttpRequest, HttpResponse};
use router::{err, ok, query_u32, query_u64, query_usize, routes, PathParams, Router};

pub use server::{serve_gateway, GatewayHandle};

/// Gateway tunables (assembled from the `[gateway]` config section plus
/// the serving knobs it shares with the TCP listener).
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    pub addr: String,
    /// 0 = auto (same policy as the TCP event core).
    pub io_threads: usize,
    /// Server-side request budget in ms (0 = none); min-combined with a
    /// client's `?timeout_ms=`, exactly like the TCP `HULL`/`SADD` forms.
    pub request_timeout_ms: u64,
    pub max_body_bytes: usize,
    /// Ceiling on `?limit=` for paginated hull reads.
    pub page_limit: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            addr: "127.0.0.1:8080".into(),
            io_threads: 0,
            request_timeout_ms: 0,
            max_body_bytes: 1 << 26,
            page_limit: 4096,
        }
    }
}

/// Shared state every handler sees.
pub struct Ctx {
    pub(crate) engine: Arc<Engine>,
    pub(crate) metrics: Arc<GatewayMetrics>,
    pub(crate) request_timeout_ms: u64,
    pub(crate) page_limit: usize,
}

/// The gateway's route table.
pub(crate) fn build_router() -> Router<Ctx> {
    routes! {
        Post   "/v1/hull"                  => GatewayRoute::Hull,         h_hull;
        Post   "/v1/sessions"              => GatewayRoute::SessionOpen,  h_session_open;
        Post   "/v1/sessions/{sid}/points" => GatewayRoute::SessionAdd,   h_session_add;
        Get    "/v1/sessions/{sid}/hull"   => GatewayRoute::SessionHull,  h_session_hull;
        Delete "/v1/sessions/{sid}"        => GatewayRoute::SessionClose, h_session_close;
        Get    "/v1/stats"                 => GatewayRoute::Stats,        h_stats;
        Get    "/healthz"                  => GatewayRoute::Healthz,      h_healthz;
        Get    "/readyz"                   => GatewayRoute::Readyz,       h_readyz;
    }
}

// -------------------------------------------------------------- bodies

fn points_json(pts: &[Point]) -> Json {
    Json::Arr(pts.iter().map(|p| Json::Arr(vec![Json::Num(p.x), Json::Num(p.y)])).collect())
}

/// Decode the request body into points: raw little-endian `f64` pairs
/// under `application/octet-stream` (the binary frame payload encoding,
/// decoded by the same `frame::read_points`), JSON
/// `{"points":[[x,y],...]}` otherwise.  Returns the points plus the
/// optional `"id"` field (JSON only).
fn body_points(req: &HttpRequest) -> Result<(Vec<Point>, Option<u64>), HttpResponse> {
    let ct = req.header("content-type").unwrap_or("application/json");
    if ct.starts_with("application/octet-stream") {
        if req.body.len() % 16 != 0 {
            return err!(
                400,
                "bad-binary-body",
                format!("octet-stream body must be 16-byte x,y pairs, got {} bytes", req.body.len())
            );
        }
        let count = req.body.len() / 16;
        if count > MAX_REQUEST_POINTS {
            return err!(
                413,
                "too-many-points",
                format!("{count} points exceeds the per-request cap of {MAX_REQUEST_POINTS}")
            );
        }
        return Ok((frame::read_points(&req.body, count), None));
    }
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| HttpResponse::error(400, "bad-json", "body is not utf-8"))?;
    let doc = json::parse(text)
        .map_err(|e| HttpResponse::error(400, "bad-json", &format!("body is not JSON: {e}")))?;
    let arr = doc
        .get("points")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| HttpResponse::error(400, "bad-json", "body wants a \"points\" array"))?;
    if arr.len() > MAX_REQUEST_POINTS {
        return err!(
            413,
            "too-many-points",
            format!("{} points exceeds the per-request cap of {MAX_REQUEST_POINTS}", arr.len())
        );
    }
    let mut pts = Vec::with_capacity(arr.len());
    for (i, el) in arr.iter().enumerate() {
        let pair = el.as_arr().filter(|p| p.len() == 2);
        let (x, y) = match pair {
            Some(p) => match (p[0].as_f64(), p[1].as_f64()) {
                (Some(x), Some(y)) => (x, y),
                _ => {
                    return err!(400, "bad-json", format!("points[{i}] wants two numbers"));
                }
            },
            None => {
                return err!(400, "bad-json", format!("points[{i}] wants an [x, y] pair"));
            }
        };
        pts.push(Point::new(x, y));
    }
    let id = doc.get("id").and_then(|v| v.as_f64()).map(|v| v as u64);
    Ok((pts, id))
}

fn session_err(e: &crate::stream::SessionError) -> HttpResponse {
    HttpResponse::error(
        errors::http_status_of_session(e),
        errors::code_of_session(e),
        &e.to_string(),
    )
}

// ------------------------------------------------------------ handlers

fn h_hull(ctx: &Ctx, req: &HttpRequest, _p: &PathParams) -> Result<HttpResponse, HttpResponse> {
    let tmo = query_u32(req, "timeout_ms")?;
    let deadline = request_deadline(ctx.request_timeout_ms, tmo);
    let (points, body_id) = body_points(req)?;
    let id = match query_u64(req, "id")? {
        Some(id) => id,
        None => body_id.unwrap_or(0),
    };
    // Park-on-recv mirrors the threaded TCP shim: handlers run on the
    // gateway's bounded dispatch pool, never on an I/O thread.
    let reply = ctx.engine.submit(HullRequest::new(id, points).with_deadline(deadline));
    match reply.recv() {
        Ok(Ok(h)) => ok!(
            "id" => Json::Num(id as f64),
            "upper" => points_json(&h.upper),
            "lower" => points_json(&h.lower),
            "backend" => Json::Str(h.backend.to_string()),
        ),
        Ok(Err(e)) => err!(
            errors::http_status_of_request(&e),
            errors::code_of_request(&e),
            e.to_string()
        ),
        Err(_) => err!(502, "backend-failure", "coordinator gone"),
    }
}

fn h_session_open(
    ctx: &Ctx,
    req: &HttpRequest,
    _p: &PathParams,
) -> Result<HttpResponse, HttpResponse> {
    let restore = if req.body.is_empty() {
        None
    } else {
        let text = std::str::from_utf8(&req.body)
            .map_err(|_| HttpResponse::error(400, "bad-json", "body is not utf-8"))?;
        let doc = json::parse(text)
            .map_err(|e| HttpResponse::error(400, "bad-json", &format!("body is not JSON: {e}")))?;
        match doc.get("restore") {
            None => None,
            Some(v) => match v.as_f64().filter(|x| *x >= 1.0 && x.fract() == 0.0) {
                Some(sid) => Some(sid as u64),
                None => {
                    return err!(400, "bad-json", "\"restore\" wants a positive session id");
                }
            },
        }
    };
    let opened = match restore {
        None => ctx.engine.session_open(),
        Some(sid) => ctx.engine.session_restore(sid),
    };
    match opened {
        Ok(sid) => ok!(
            "sid" => Json::Num(sid as f64),
            "restored" => Json::Bool(restore.is_some()),
        ),
        Err(e) => Err(session_err(&e)),
    }
}

fn h_session_add(
    ctx: &Ctx,
    req: &HttpRequest,
    p: &PathParams,
) -> Result<HttpResponse, HttpResponse> {
    let sid = p.u64("sid")?;
    let tmo = query_u32(req, "timeout_ms")?;
    let deadline = request_deadline(ctx.request_timeout_ms, tmo);
    let (points, _) = body_points(req)?;
    match ctx.engine.session_add_deadline(sid, &points, deadline) {
        Ok(o) => ok!(
            "sid" => Json::Num(sid as f64),
            "absorbed" => Json::Num(o.absorbed as f64),
            "pending" => Json::Num(o.pending as f64),
            "epoch" => Json::Num(o.epoch as f64),
        ),
        Err(e) => Err(session_err(&e)),
    }
}

fn h_session_hull(
    ctx: &Ctx,
    req: &HttpRequest,
    p: &PathParams,
) -> Result<HttpResponse, HttpResponse> {
    let sid = p.u64("sid")?;
    let cur = match req.query("cursor") {
        None => None,
        Some(raw) => match cursor::decode(raw) {
            Some(c) => Some(c),
            None => {
                return err!(400, "bad-cursor", "cursor is not one this server issued");
            }
        },
    };
    let epoch_q = query_u64(req, "epoch")?;
    if let (Some(c), Some(e)) = (&cur, epoch_q) {
        if c.epoch != e {
            return err!(
                400,
                "bad-cursor",
                format!("cursor pins epoch {} but the query asks for epoch {e}", c.epoch)
            );
        }
    }
    let limit = query_usize(req, "limit")?
        .unwrap_or(ctx.page_limit)
        .min(ctx.page_limit)
        .max(1);
    // A cursor pins its epoch; without one, ?epoch= (or the live hull)
    // decides, and the epoch we resolve here rides in next_cursor so
    // every later page reads the same immutable ledger entry.
    let want_epoch = cur.map(|c| c.epoch).or(epoch_q);
    let snap = match ctx.engine.session_hull_at(sid, want_epoch) {
        Ok(s) => s,
        Err(e) => return Err(session_err(&e)),
    };
    let at = cur.unwrap_or(cursor::Cursor { epoch: snap.epoch, chain: 0, offset: 0 });
    let page = cursor::page(&snap.upper, &snap.lower, at, limit);
    ok!(
        "sid" => Json::Num(sid as f64),
        "epoch" => Json::Num(snap.epoch as f64),
        "upper" => points_json(&page.upper),
        "lower" => points_json(&page.lower),
        "next_cursor" => match page.next {
            Some(n) => Json::Str(cursor::encode(&n)),
            None => Json::Null,
        },
    )
}

fn h_session_close(
    ctx: &Ctx,
    _req: &HttpRequest,
    p: &PathParams,
) -> Result<HttpResponse, HttpResponse> {
    let sid = p.u64("sid")?;
    match ctx.engine.session_close(sid) {
        Ok(()) => ok!("sid" => Json::Num(sid as f64), "closed" => Json::Bool(true)),
        Err(e) => Err(session_err(&e)),
    }
}

fn h_stats(ctx: &Ctx, _req: &HttpRequest, _p: &PathParams) -> Result<HttpResponse, HttpResponse> {
    let active = ctx.metrics.open_connections.load(Ordering::Relaxed);
    Ok(HttpResponse::json(200, ctx.engine.stats(Some(active)).0))
}

fn h_healthz(ctx: &Ctx, _req: &HttpRequest, _p: &PathParams) -> Result<HttpResponse, HttpResponse> {
    ok!(
        "ok" => Json::Bool(true),
        "backend" => Json::Str(ctx.engine.backend_name().to_string()),
        "shards" => Json::Num(ctx.engine.shard_count() as f64),
    )
}

/// Readiness degrades (503) while any shard's breaker is open or the
/// session table is full — the conditions under which new work is shed.
fn h_readyz(ctx: &Ctx, _req: &HttpRequest, _p: &PathParams) -> Result<HttpResponse, HttpResponse> {
    let mut reasons = Vec::new();
    for i in 0..ctx.engine.shard_count() {
        if ctx.engine.shard_coordinator(i).breaker().state() == 1 {
            reasons.push(Json::Str(format!("shard {i} breaker open")));
        }
    }
    let open = ctx.engine.open_sessions();
    let max = ctx.engine.max_sessions();
    if open >= max {
        reasons.push(Json::Str(format!("session table full ({open}/{max})")));
    }
    let ready = reasons.is_empty();
    let body = Json::obj(vec![("ready", Json::Bool(ready)), ("reasons", Json::Arr(reasons))]);
    Ok(HttpResponse::json(if ready { 200 } else { 503 }, body))
}

// ----------------------------------------------------------- accounting

/// Record one finished exchange into the shared sink and the request
/// log — the single choke point both server cores call.
pub(crate) fn observe_exchange(
    ctx: &Ctx,
    route: GatewayRoute,
    sid: Option<u64>,
    status: u16,
    bytes_in: u64,
    bytes_out: u64,
    started: Instant,
) {
    let ns = started.elapsed().as_nanos() as u64;
    ctx.metrics.observe(route, status, bytes_in, bytes_out, ns);
    let shard = match sid {
        Some(sid) => ctx.engine.shard_of(sid).to_string(),
        None => "-".into(),
    };
    crate::log_info!(
        "gw {} status={status} bytes_in={bytes_in} bytes_out={bytes_out} latency_us={} shard={shard}",
        route.name(),
        ns / 1000,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendKind, CoordinatorConfig};
    use crate::engine::{Engine, EngineConfig};
    use crate::server::proto::Decoded;

    fn test_ctx() -> Ctx {
        let engine = Arc::new(
            Engine::start(EngineConfig {
                shards: 1,
                coordinator: CoordinatorConfig {
                    backend: BackendKind::Serial,
                    workers: 1,
                    ..Default::default()
                },
                ..Default::default()
            })
            .expect("engine"),
        );
        let metrics = engine.register_gateway_metrics();
        Ctx { engine, metrics, request_timeout_ms: 0, page_limit: 4096 }
    }

    fn http(ctx: &Ctx, wire: &str) -> (u16, Json) {
        let req = match http::decode_request(wire.as_bytes(), 1 << 20).unwrap() {
            Decoded::Frame(r, _) => r,
            Decoded::Need(n) => panic!("test request incomplete (need {n})"),
        };
        let d = build_router().dispatch(ctx, &req);
        let body = json::parse(std::str::from_utf8(&d.resp.body).unwrap()).unwrap();
        (d.resp.status, body)
    }

    #[test]
    fn hull_roundtrips_through_json() {
        let ctx = test_ctx();
        let body = r#"{"id": 7, "points": [[0,0],[2,0],[1,5],[1,1]]}"#;
        let wire = format!(
            "POST /v1/hull HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        );
        let (status, j) = http(&ctx, &wire);
        assert_eq!(status, 200, "{j}");
        assert_eq!(j.get("id").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(j.get("upper").and_then(|v| v.as_arr()).unwrap().len(), 3);
    }

    #[test]
    fn session_lifecycle_over_http() {
        let ctx = test_ctx();
        let (status, j) = http(&ctx, "POST /v1/sessions HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200, "{j}");
        let sid = j.get("sid").and_then(|v| v.as_f64()).unwrap() as u64;
        let body = r#"{"points": [[0,0],[4,0],[2,9]]}"#;
        let (status, j) = http(
            &ctx,
            &format!(
                "POST /v1/sessions/{sid}/points HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
                body.len()
            ),
        );
        assert_eq!(status, 200, "{j}");
        let (status, j) = http(&ctx, &format!("GET /v1/sessions/{sid}/hull HTTP/1.1\r\n\r\n"));
        assert_eq!(status, 200, "{j}");
        assert!(j.get("next_cursor") == Some(&Json::Null));
        let (status, _) = http(&ctx, &format!("DELETE /v1/sessions/{sid} HTTP/1.1\r\n\r\n"));
        assert_eq!(status, 200);
        let (status, j) = http(&ctx, &format!("GET /v1/sessions/{sid}/hull HTTP/1.1\r\n\r\n"));
        assert_eq!(status, 404);
        let code = j.get("error").and_then(|e| e.get("code")).cloned();
        assert_eq!(code, Some(Json::Str("unknown-session".into())));
    }

    #[test]
    fn bad_cursor_and_conflicting_epoch_are_400s() {
        let ctx = test_ctx();
        let (status, j) = http(&ctx, "GET /v1/sessions/1/hull?cursor=junk HTTP/1.1\r\n\r\n");
        assert_eq!(status, 400);
        assert_eq!(
            j.get("error").and_then(|e| e.get("code")).cloned(),
            Some(Json::Str("bad-cursor".into()))
        );
        let c = cursor::encode(&cursor::Cursor { epoch: 2, chain: 0, offset: 0 });
        let (status, j) =
            http(&ctx, &format!("GET /v1/sessions/1/hull?cursor={c}&epoch=5 HTTP/1.1\r\n\r\n"));
        assert_eq!(status, 400, "{j}");
    }

    #[test]
    fn stats_and_probes_answer() {
        let ctx = test_ctx();
        let (status, j) = http(&ctx, "GET /v1/stats HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        assert!(j.get("gateway").is_some(), "stats wants the gateway object");
        assert!(j.get("io").is_some(), "stats wants the io object");
        let (status, _) = http(&ctx, "GET /healthz HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        let (status, j) = http(&ctx, "GET /readyz HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        assert_eq!(j.get("ready"), Some(&Json::Bool(true)));
    }

    #[test]
    fn binary_bodies_decode_like_the_frame_payload() {
        let ctx = test_ctx();
        let mut body = Vec::new();
        for (x, y) in [(0.0, 0.0), (3.0, 0.0), (1.5, 4.0)] {
            body.extend_from_slice(&f64::to_le_bytes(x));
            body.extend_from_slice(&f64::to_le_bytes(y));
        }
        let mut wire = format!(
            "POST /v1/hull?id=9 HTTP/1.1\r\ncontent-type: application/octet-stream\r\ncontent-length: {}\r\n\r\n",
            body.len()
        )
        .into_bytes();
        wire.extend_from_slice(&body);
        let req = match http::decode_request(&wire, 1 << 20).unwrap() {
            Decoded::Frame(r, _) => r,
            Decoded::Need(_) => panic!("incomplete"),
        };
        let d = build_router().dispatch(&ctx, &req);
        assert_eq!(d.resp.status, 200);
        // truncated pair → typed 400
        let mut wire = b"POST /v1/hull HTTP/1.1\r\ncontent-type: application/octet-stream\r\ncontent-length: 15\r\n\r\n".to_vec();
        wire.extend_from_slice(&[0u8; 15]);
        let req = match http::decode_request(&wire, 1 << 20).unwrap() {
            Decoded::Frame(r, _) => r,
            Decoded::Need(_) => panic!("incomplete"),
        };
        let d = build_router().dispatch(&ctx, &req);
        assert_eq!(d.resp.status, 400);
    }
}
