//! Network front-end: a line-oriented text protocol over TCP (the paper's
//! own file format extended with framing), a threaded server, and a
//! blocking client used by the examples, benches and integration tests.

pub mod client;
pub mod proto;
pub mod tcp;

pub use client::HullClient;
pub use proto::{Request, Response};
pub use tcp::{serve, ServerConfig, ServerHandle};
