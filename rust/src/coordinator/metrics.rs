//! Serving metrics: counters + log-bucketed latency histograms with
//! percentile estimation.  Lock-light: all atomics, safe to share via Arc.
//!
//! Snapshots go through [`MetricsFrame`], a plain-value copy in which every
//! atomic is loaded exactly once.  That single-read rule is what keeps a
//! multi-shard aggregate internally consistent: the engine takes one frame
//! per shard and sums the frames, so a gauge pair like `open_sessions` /
//! `pending_points_total` can never mix reads from two different moments
//! of the same shard (which could show pending points for a session
//! another field says is closed).  Frames merge exactly: counters and
//! gauges sum, histograms merge bucket-wise.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

const BUCKETS: usize = 48; // log2 ns buckets: covers 1 ns .. ~3 days

/// Log2-bucketed latency histogram (nanoseconds).
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
    count: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record_ns(&self, ns: u64) {
        let b = (64 - ns.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.counts[b].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        self.snap().mean_ns()
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Percentile estimate (upper bucket bound), q in [0, 1].  The
    /// estimator lives on [`HistogramSnapshot`] — one copy of the
    /// algorithm whether the buckets come from a live histogram or a
    /// merged multi-shard frame.
    pub fn percentile_ns(&self, q: f64) -> u64 {
        self.snap().percentile_ns(q)
    }

    /// Plain-value copy of the histogram (each atomic loaded once).
    pub fn snap(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            counts: std::array::from_fn(|b| self.counts[b].load(Ordering::Relaxed)),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of one [`Histogram`].  Unlike the JSON percentile
/// summary, this keeps the raw buckets, so two snapshots merge *exactly*
/// (bucket-wise sum) — percentiles of a merged frame are computed from the
/// combined distribution, never averaged from per-shard percentiles.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    counts: [u64; BUCKETS],
    sum_ns: u64,
    count: u64,
    max_ns: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot { counts: [0; BUCKETS], sum_ns: 0, count: 0, max_ns: 0 }
    }
}

impl HistogramSnapshot {
    /// Fold `other` in: buckets/sums/counts add, max takes the max.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.sum_ns += other.sum_ns;
        self.count += other.count;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Percentile estimate (upper bucket bound), q in [0, 1] — same
    /// estimator as [`Histogram::percentile_ns`], over the merged buckets.
    pub fn percentile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (b + 1);
            }
        }
        self.max_ns
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_ns", Json::Num(self.mean_ns())),
            ("p50_ns", Json::Num(self.percentile_ns(0.50) as f64)),
            ("p95_ns", Json::Num(self.percentile_ns(0.95) as f64)),
            ("p99_ns", Json::Num(self.percentile_ns(0.99) as f64)),
            ("max_ns", Json::Num(self.max_ns as f64)),
        ])
    }
}

/// All coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub degenerate_fallbacks: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub points_in: AtomicU64,
    pub hull_points_out: AtomicU64,
    /// points dropped by the octagon pre-filter on the host (submit-path
    /// `prepare()` in Host mode, or worker-side fallback in Device mode).
    pub filtered_points_host: AtomicU64,
    /// points dropped by the on-device Pallas filter kernel.
    pub filtered_points_device: AtomicU64,
    /// points fed into the device filter (denominator of the compaction
    /// ratio — host-fallback traffic is excluded by design).
    pub device_filter_points_in: AtomicU64,
    /// streaming-session merges served by the device tangent kernel; each
    /// one is exactly one upload + one download.
    pub device_tangent_merges: AtomicU64,
    pub queue_latency: Histogram,
    pub exec_latency: Histogram,
    pub e2e_latency: Histogram,
    // ---- robustness (deadlines, shedding, failover) ----
    /// requests answered `deadline-exceeded` (admission, batcher dequeue,
    /// or pre-dispatch expiry).  Every one also counts in `errors`.
    pub deadline_exceeded: AtomicU64,
    /// requests answered `overloaded` by admission control (the shard was
    /// at its `max_queued` ceiling).  NOT part of `errors`: shed requests
    /// never entered the pipeline, so they must not skew `in_flight`.
    pub shed: AtomicU64,
    /// batches re-dispatched after a backend failure (one bounded retry).
    pub retries: AtomicU64,
    /// circuit-breaker state gauge: 0 closed, 1 open, 2 half-open.
    /// Merged across shards it reads as "sum of shard states" — use
    /// `per_shard` for the individual breakers.
    pub breaker_state: AtomicU64,
    // ---- streaming sessions (maintained by stream::SessionRegistry) ----
    /// currently open sessions (gauge).
    pub open_sessions: AtomicU64,
    /// points proven interior and dropped (insert-time rejection + merge
    /// consolidation), lifetime total across sessions.
    pub session_absorbed_points: AtomicU64,
    /// points sitting in pending buffers right now (gauge).
    pub session_pending_points: AtomicU64,
    /// incremental re-hulls performed (threshold or explicit flush).
    pub session_merges: AtomicU64,
    /// sessions reaped by the idle-TTL sweep.
    pub session_evictions: AtomicU64,
    /// wall time of each incremental merge (backend round-trip included).
    pub session_merge_latency: Histogram,
    // ---- durable sessions (snapshot store) ----
    /// session snapshots committed to the store (merge, close, evict).
    pub snapshots_written: AtomicU64,
    /// sessions restored from the store by `SOPEN <sid>`.
    pub restores: AtomicU64,
    /// bytes actually written to the store (new chunks + manifests;
    /// deduplicated chunks cost nothing).
    pub snapshot_bytes: AtomicU64,
}

/// A point-in-time copy, JSON-serializable for the STATS endpoint.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot(pub Json);

impl Metrics {
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Decrement a gauge (callers pair every `sub` with an earlier `add`,
    /// so this cannot underflow in correct use).
    pub fn sub(counter: &AtomicU64, v: u64) {
        counter.fetch_sub(v, Ordering::Relaxed);
    }

    /// Plain-value copy of every metric, each atomic loaded exactly once.
    /// This is the unit of aggregation: the engine snapshots one frame per
    /// shard and merges the frames, so related gauges always come from the
    /// same per-shard read.
    pub fn frame(&self) -> MetricsFrame {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        MetricsFrame {
            requests: g(&self.requests),
            responses: g(&self.responses),
            errors: g(&self.errors),
            degenerate_fallbacks: g(&self.degenerate_fallbacks),
            batches: g(&self.batches),
            batched_requests: g(&self.batched_requests),
            points_in: g(&self.points_in),
            hull_points_out: g(&self.hull_points_out),
            filtered_points_host: g(&self.filtered_points_host),
            filtered_points_device: g(&self.filtered_points_device),
            device_filter_points_in: g(&self.device_filter_points_in),
            device_tangent_merges: g(&self.device_tangent_merges),
            queue_latency: self.queue_latency.snap(),
            exec_latency: self.exec_latency.snap(),
            e2e_latency: self.e2e_latency.snap(),
            deadline_exceeded: g(&self.deadline_exceeded),
            shed: g(&self.shed),
            retries: g(&self.retries),
            breaker_state: g(&self.breaker_state),
            open_sessions: g(&self.open_sessions),
            session_absorbed_points: g(&self.session_absorbed_points),
            session_pending_points: g(&self.session_pending_points),
            session_merges: g(&self.session_merges),
            session_evictions: g(&self.session_evictions),
            session_merge_latency: self.session_merge_latency.snap(),
            snapshots_written: g(&self.snapshots_written),
            restores: g(&self.restores),
            snapshot_bytes: g(&self.snapshot_bytes),
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot(self.frame().to_json())
    }

    /// One-shot requests in flight right now (three relaxed loads — the
    /// engine's hot routing signal; use [`Metrics::frame`] when the whole
    /// consistent picture is needed).
    pub fn in_flight(&self) -> u64 {
        let served = self.responses.load(Ordering::Relaxed) + self.errors.load(Ordering::Relaxed);
        self.requests.load(Ordering::Relaxed).saturating_sub(served)
    }
}

/// One coherent point-in-time copy of a [`Metrics`] sink.  Counters and
/// gauges sum under [`MetricsFrame::merge`]; histograms merge bucket-wise.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsFrame {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub degenerate_fallbacks: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub points_in: u64,
    pub hull_points_out: u64,
    pub filtered_points_host: u64,
    pub filtered_points_device: u64,
    pub device_filter_points_in: u64,
    pub device_tangent_merges: u64,
    pub queue_latency: HistogramSnapshot,
    pub exec_latency: HistogramSnapshot,
    pub e2e_latency: HistogramSnapshot,
    pub deadline_exceeded: u64,
    pub shed: u64,
    pub retries: u64,
    pub breaker_state: u64,
    pub open_sessions: u64,
    pub session_absorbed_points: u64,
    pub session_pending_points: u64,
    pub session_merges: u64,
    pub session_evictions: u64,
    pub session_merge_latency: HistogramSnapshot,
    pub snapshots_written: u64,
    pub restores: u64,
    pub snapshot_bytes: u64,
}

impl MetricsFrame {
    /// Fold another shard's frame in: counters and gauges sum, histograms
    /// merge bucket-wise.  `mean_batch_size` is derived at serialization
    /// time from the merged totals, never averaged.
    pub fn merge(&mut self, other: &MetricsFrame) {
        self.requests += other.requests;
        self.responses += other.responses;
        self.errors += other.errors;
        self.degenerate_fallbacks += other.degenerate_fallbacks;
        self.batches += other.batches;
        self.batched_requests += other.batched_requests;
        self.points_in += other.points_in;
        self.hull_points_out += other.hull_points_out;
        self.filtered_points_host += other.filtered_points_host;
        self.filtered_points_device += other.filtered_points_device;
        self.device_filter_points_in += other.device_filter_points_in;
        self.device_tangent_merges += other.device_tangent_merges;
        self.queue_latency.merge(&other.queue_latency);
        self.exec_latency.merge(&other.exec_latency);
        self.e2e_latency.merge(&other.e2e_latency);
        self.deadline_exceeded += other.deadline_exceeded;
        self.shed += other.shed;
        self.retries += other.retries;
        self.breaker_state += other.breaker_state;
        self.open_sessions += other.open_sessions;
        self.session_absorbed_points += other.session_absorbed_points;
        self.session_pending_points += other.session_pending_points;
        self.session_merges += other.session_merges;
        self.session_evictions += other.session_evictions;
        self.session_merge_latency.merge(&other.session_merge_latency);
        self.snapshots_written += other.snapshots_written;
        self.restores += other.restores;
        self.snapshot_bytes += other.snapshot_bytes;
    }

    /// One-shot requests currently in flight (submitted, not yet answered
    /// or failed) — the engine's cheapest-queue routing signal.
    pub fn in_flight(&self) -> u64 {
        self.requests.saturating_sub(self.responses + self.errors)
    }

    /// The STATS JSON object (same shape as the pre-frame snapshot).
    pub fn to_json(&self) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        Json::obj(vec![
            ("requests", n(self.requests)),
            ("responses", n(self.responses)),
            ("errors", n(self.errors)),
            ("degenerate_fallbacks", n(self.degenerate_fallbacks)),
            ("batches", n(self.batches)),
            ("batched_requests", n(self.batched_requests)),
            (
                "mean_batch_size",
                Json::Num(if self.batches == 0 {
                    0.0
                } else {
                    self.batched_requests as f64 / self.batches as f64
                }),
            ),
            ("points_in", n(self.points_in)),
            ("hull_points_out", n(self.hull_points_out)),
            // compat key: pre-PR 10 consumers read the sum
            (
                "filtered_points",
                n(self.filtered_points_host + self.filtered_points_device),
            ),
            ("filtered_points_host", n(self.filtered_points_host)),
            ("filtered_points_device", n(self.filtered_points_device)),
            ("device_filter_points_in", n(self.device_filter_points_in)),
            // fraction of device-filtered points that SURVIVE (1.0 when the
            // device filter has seen no traffic)
            (
                "device_filter_compaction",
                Json::Num(if self.device_filter_points_in == 0 {
                    1.0
                } else {
                    (self.device_filter_points_in - self.filtered_points_device) as f64
                        / self.device_filter_points_in as f64
                }),
            ),
            ("device_tangent_merges", n(self.device_tangent_merges)),
            ("queue_latency", self.queue_latency.to_json()),
            ("exec_latency", self.exec_latency.to_json()),
            ("e2e_latency", self.e2e_latency.to_json()),
            ("deadline_exceeded_total", n(self.deadline_exceeded)),
            ("shed_total", n(self.shed)),
            ("retries_total", n(self.retries)),
            ("breaker_state", n(self.breaker_state)),
            ("open_sessions", n(self.open_sessions)),
            ("absorbed_points_total", n(self.session_absorbed_points)),
            ("pending_points_total", n(self.session_pending_points)),
            ("merges_total", n(self.session_merges)),
            ("session_evictions", n(self.session_evictions)),
            ("session_merge_latency", self.session_merge_latency.to_json()),
            ("snapshots_written_total", n(self.snapshots_written)),
            ("restores_total", n(self.restores)),
            ("snapshot_bytes_total", n(self.snapshot_bytes)),
        ])
    }
}

/// Gauges owned by one I/O event-loop thread (written only from that
/// thread, read by STATS).
#[derive(Debug, Default)]
pub struct IoLoopMetrics {
    /// connections currently registered with this loop (gauge).
    pub open_connections: AtomicU64,
    /// bytes read off sockets by this loop, lifetime.
    pub bytes_in: AtomicU64,
    /// bytes written to sockets by this loop, lifetime.
    pub bytes_out: AtomicU64,
}

/// I/O-layer metrics for the event-loop server: shared counters plus one
/// gauge block per loop thread, folded into STATS under the `io` key.
#[derive(Debug)]
pub struct IoMetrics {
    /// connections accepted, lifetime.
    pub accepted: AtomicU64,
    /// complete text frames decoded.
    pub frames_text: AtomicU64,
    /// complete binary frames decoded.
    pub frames_binary: AtomicU64,
    /// times a connection's reads were paused on a full write buffer.
    pub backpressure_stalls: AtomicU64,
    /// wall time spent decoding each complete frame.
    pub decode_latency: Histogram,
    pub loops: Vec<IoLoopMetrics>,
}

impl IoMetrics {
    pub fn new(io_threads: usize) -> IoMetrics {
        IoMetrics {
            accepted: AtomicU64::new(0),
            frames_text: AtomicU64::new(0),
            frames_binary: AtomicU64::new(0),
            backpressure_stalls: AtomicU64::new(0),
            decode_latency: Histogram::default(),
            loops: (0..io_threads).map(|_| IoLoopMetrics::default()).collect(),
        }
    }

    /// Connections open across all loops right now.
    pub fn open_connections(&self) -> u64 {
        self.loops.iter().map(|l| l.open_connections.load(Ordering::Relaxed)).sum()
    }

    /// The `io` object of the STATS JSON.
    pub fn to_json(&self) -> Json {
        let g = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        let per_loop: Vec<Json> = self
            .loops
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("open_connections", g(&l.open_connections)),
                    ("bytes_in", g(&l.bytes_in)),
                    ("bytes_out", g(&l.bytes_out)),
                ])
            })
            .collect();
        let sum = |f: fn(&IoLoopMetrics) -> &AtomicU64| {
            Json::Num(
                self.loops.iter().map(|l| f(l).load(Ordering::Relaxed)).sum::<u64>() as f64,
            )
        };
        Json::obj(vec![
            ("io_threads", Json::Num(self.loops.len() as f64)),
            ("accepted", g(&self.accepted)),
            ("open_connections", sum(|l| &l.open_connections)),
            ("bytes_in", sum(|l| &l.bytes_in)),
            ("bytes_out", sum(|l| &l.bytes_out)),
            ("frames_text", g(&self.frames_text)),
            ("frames_binary", g(&self.frames_binary)),
            ("backpressure_stalls", g(&self.backpressure_stalls)),
            ("decode_latency", self.decode_latency.snap().to_json()),
            ("per_loop", Json::Arr(per_loop)),
        ])
    }
}

/// The HTTP gateway's fixed route table, in match order.  Per-route
/// counters live in a fixed array indexed by this enum, so the hot path
/// is a handful of relaxed atomic adds — no map lookup, no lock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GatewayRoute {
    Hull,
    SessionOpen,
    SessionAdd,
    SessionHull,
    SessionClose,
    Stats,
    Healthz,
    Readyz,
    /// anything that matched no route (404) or died before routing (400).
    Other,
}

impl GatewayRoute {
    pub const ALL: [GatewayRoute; 9] = [
        GatewayRoute::Hull,
        GatewayRoute::SessionOpen,
        GatewayRoute::SessionAdd,
        GatewayRoute::SessionHull,
        GatewayRoute::SessionClose,
        GatewayRoute::Stats,
        GatewayRoute::Healthz,
        GatewayRoute::Readyz,
        GatewayRoute::Other,
    ];

    /// The route label used in STATS and request logs (the pattern, not
    /// the concrete path — one series per route, not per sid).
    pub const fn name(self) -> &'static str {
        match self {
            GatewayRoute::Hull => "POST /v1/hull",
            GatewayRoute::SessionOpen => "POST /v1/sessions",
            GatewayRoute::SessionAdd => "POST /v1/sessions/{sid}/points",
            GatewayRoute::SessionHull => "GET /v1/sessions/{sid}/hull",
            GatewayRoute::SessionClose => "DELETE /v1/sessions/{sid}",
            GatewayRoute::Stats => "GET /v1/stats",
            GatewayRoute::Healthz => "GET /healthz",
            GatewayRoute::Readyz => "GET /readyz",
            GatewayRoute::Other => "other",
        }
    }
}

/// One route's slice of the gateway metrics.
#[derive(Debug, Default)]
pub struct GatewayRouteMetrics {
    pub requests: AtomicU64,
    pub status_2xx: AtomicU64,
    pub status_4xx: AtomicU64,
    pub status_5xx: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub latency: Histogram,
}

/// The gateway's contribution to the shared metrics sink — folded into
/// STATS and `/v1/stats` under the `gateway` key.  A zeroed instance
/// serializes the identical schema, so the key is present (all-zero)
/// even on engines serving only the TCP listener.
#[derive(Debug)]
pub struct GatewayMetrics {
    /// HTTP connections accepted, lifetime.
    pub accepted: AtomicU64,
    /// HTTP connections open right now (gauge).
    pub open_connections: AtomicU64,
    /// requests torn down on malformed HTTP framing.
    pub decode_errors: AtomicU64,
    routes: [GatewayRouteMetrics; GatewayRoute::ALL.len()],
}

impl Default for GatewayMetrics {
    fn default() -> Self {
        GatewayMetrics {
            accepted: AtomicU64::new(0),
            open_connections: AtomicU64::new(0),
            decode_errors: AtomicU64::new(0),
            routes: std::array::from_fn(|_| GatewayRouteMetrics::default()),
        }
    }
}

impl GatewayMetrics {
    pub fn route(&self, r: GatewayRoute) -> &GatewayRouteMetrics {
        &self.routes[r as usize]
    }

    /// Record one finished exchange: request counter, status class,
    /// byte counters, latency histogram — the per-route observability
    /// contract in one call.
    pub fn observe(&self, r: GatewayRoute, status: u16, bytes_in: u64, bytes_out: u64, ns: u64) {
        let m = self.route(r);
        m.requests.fetch_add(1, Ordering::Relaxed);
        let class = match status {
            200..=299 => &m.status_2xx,
            400..=499 => &m.status_4xx,
            _ => &m.status_5xx,
        };
        class.fetch_add(1, Ordering::Relaxed);
        m.bytes_in.fetch_add(bytes_in, Ordering::Relaxed);
        m.bytes_out.fetch_add(bytes_out, Ordering::Relaxed);
        m.latency.record_ns(ns);
    }

    /// The `gateway` object of the STATS JSON.
    pub fn to_json(&self) -> Json {
        let g = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        let routes: Vec<(&str, Json)> = GatewayRoute::ALL
            .iter()
            .map(|&r| {
                let m = self.route(r);
                (
                    r.name(),
                    Json::obj(vec![
                        ("requests", g(&m.requests)),
                        ("status_2xx", g(&m.status_2xx)),
                        ("status_4xx", g(&m.status_4xx)),
                        ("status_5xx", g(&m.status_5xx)),
                        ("bytes_in", g(&m.bytes_in)),
                        ("bytes_out", g(&m.bytes_out)),
                        ("latency", m.latency.snap().to_json()),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("accepted", g(&self.accepted)),
            ("open_connections", g(&self.open_connections)),
            ("decode_errors", g(&self.decode_errors)),
            ("routes", Json::obj(routes)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile_ns(0.5);
        let p95 = h.percentile_ns(0.95);
        let p99 = h.percentile_ns(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of ~uniform 1k..1000k ns should be around 512k..1M bucket
        assert!((100_000..=2_100_000).contains(&p50), "{p50}");
        assert!((h.mean_ns() - 500_500.0 * 1.0).abs() < 100_000.0);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.percentile_ns(0.99), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn snapshot_is_json() {
        let m = Metrics::default();
        Metrics::inc(&m.requests);
        Metrics::add(&m.points_in, 100);
        m.e2e_latency.record_ns(5000);
        let snap = m.snapshot();
        let s = snap.0.to_string();
        let back = crate::util::json::parse(&s).unwrap();
        assert_eq!(back.get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(back.get("points_in").unwrap().as_usize(), Some(100));
        assert_eq!(
            back.get("e2e_latency").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn snapshot_carries_session_gauges() {
        let m = Metrics::default();
        Metrics::add(&m.open_sessions, 3);
        Metrics::sub(&m.open_sessions, 1);
        Metrics::add(&m.session_pending_points, 42);
        Metrics::inc(&m.session_merges);
        m.session_merge_latency.record_ns(1234);
        let snap = crate::util::json::parse(&m.snapshot().0.to_string()).unwrap();
        assert_eq!(snap.get("open_sessions").unwrap().as_usize(), Some(2));
        assert_eq!(snap.get("pending_points_total").unwrap().as_usize(), Some(42));
        assert_eq!(snap.get("merges_total").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("absorbed_points_total").unwrap().as_usize(), Some(0));
        assert_eq!(
            snap.get("session_merge_latency").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn robustness_counters_snapshot_and_merge() {
        let a = Metrics::default();
        let b = Metrics::default();
        Metrics::add(&a.deadline_exceeded, 2);
        Metrics::add(&b.shed, 3);
        Metrics::inc(&a.retries);
        b.breaker_state.store(1, Ordering::Relaxed);
        let mut merged = a.frame();
        merged.merge(&b.frame());
        assert_eq!(merged.deadline_exceeded, 2);
        assert_eq!(merged.shed, 3);
        assert_eq!(merged.retries, 1);
        assert_eq!(merged.breaker_state, 1);
        let j = crate::util::json::parse(&merged.to_json().to_string()).unwrap();
        assert_eq!(j.get("deadline_exceeded_total").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("shed_total").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("retries_total").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("breaker_state").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn snapshot_counters_merge_and_serialize() {
        let a = Metrics::default();
        let b = Metrics::default();
        Metrics::add(&a.snapshots_written, 2);
        Metrics::inc(&b.restores);
        Metrics::add(&a.snapshot_bytes, 640);
        Metrics::add(&b.snapshot_bytes, 360);
        let mut merged = a.frame();
        merged.merge(&b.frame());
        assert_eq!(merged.snapshots_written, 2);
        assert_eq!(merged.restores, 1);
        assert_eq!(merged.snapshot_bytes, 1000);
        let j = crate::util::json::parse(&merged.to_json().to_string()).unwrap();
        assert_eq!(j.get("snapshots_written_total").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("restores_total").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("snapshot_bytes_total").unwrap().as_usize(), Some(1000));
    }

    #[test]
    fn filter_split_keeps_the_compat_sum_and_derives_compaction() {
        let a = Metrics::default();
        let b = Metrics::default();
        Metrics::add(&a.filtered_points_host, 30);
        Metrics::add(&b.filtered_points_device, 700);
        Metrics::add(&b.device_filter_points_in, 1000);
        Metrics::inc(&b.device_tangent_merges);
        let mut merged = a.frame();
        merged.merge(&b.frame());
        let j = crate::util::json::parse(&merged.to_json().to_string()).unwrap();
        // pre-PR 10 consumers keep reading the sum under the old key
        assert_eq!(j.get("filtered_points").unwrap().as_usize(), Some(730));
        assert_eq!(j.get("filtered_points_host").unwrap().as_usize(), Some(30));
        assert_eq!(j.get("filtered_points_device").unwrap().as_usize(), Some(700));
        assert_eq!(j.get("device_filter_points_in").unwrap().as_usize(), Some(1000));
        // 300 of 1000 survive the device filter
        assert_eq!(j.get("device_filter_compaction").unwrap().as_f64(), Some(0.3));
        assert_eq!(j.get("device_tangent_merges").unwrap().as_usize(), Some(1));
        // an idle device filter reads as "everything survives"
        let idle = Metrics::default().frame().to_json();
        assert_eq!(idle.get("device_filter_compaction").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn max_tracked() {
        let h = Histogram::default();
        h.record_ns(10);
        h.record_ns(99999);
        h.record_ns(50);
        assert_eq!(h.max_ns(), 99999);
    }

    #[test]
    fn histogram_snapshots_merge_bucket_wise() {
        let a = Histogram::default();
        let b = Histogram::default();
        for i in 1..=500u64 {
            a.record_ns(i * 1000);
        }
        for i in 501..=1000u64 {
            b.record_ns(i * 1000);
        }
        let mut merged = a.snap();
        merged.merge(&b.snap());
        // the merged distribution must equal one histogram fed everything
        let whole = Histogram::default();
        for i in 1..=1000u64 {
            whole.record_ns(i * 1000);
        }
        assert_eq!(merged, whole.snap());
        assert_eq!(merged.count(), 1000);
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(merged.percentile_ns(q), whole.percentile_ns(q), "q={q}");
        }
        assert_eq!(merged.max_ns, 1_000_000); // 1000 * 1000 ns
    }

    #[test]
    fn frames_merge_counters_gauges_and_histograms() {
        let a = Metrics::default();
        let b = Metrics::default();
        Metrics::add(&a.requests, 3);
        Metrics::add(&b.requests, 5);
        Metrics::add(&a.open_sessions, 2);
        Metrics::add(&b.open_sessions, 7);
        Metrics::add(&a.session_pending_points, 100);
        Metrics::add(&b.batches, 2);
        Metrics::add(&b.batched_requests, 6);
        a.e2e_latency.record_ns(10);
        b.e2e_latency.record_ns(1 << 30);
        let mut merged = a.frame();
        merged.merge(&b.frame());
        assert_eq!(merged.requests, 8);
        assert_eq!(merged.open_sessions, 9);
        assert_eq!(merged.session_pending_points, 100);
        assert_eq!(merged.e2e_latency.count(), 2);
        assert_eq!(merged.e2e_latency.max_ns, 1 << 30);
        let json = merged.to_json();
        // mean_batch_size derives from the merged totals (6 reqs / 2 batches)
        assert_eq!(json.get("mean_batch_size").unwrap().as_f64(), Some(3.0));
        assert_eq!(json.get("requests").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn frame_json_matches_snapshot_json() {
        let m = Metrics::default();
        Metrics::inc(&m.requests);
        Metrics::add(&m.points_in, 41);
        m.queue_latency.record_ns(77);
        assert_eq!(m.frame().to_json().to_string(), m.snapshot().0.to_string());
    }

    #[test]
    fn io_metrics_fold_per_loop_gauges() {
        let io = IoMetrics::new(2);
        Metrics::inc(&io.accepted);
        Metrics::add(&io.loops[0].open_connections, 3);
        Metrics::add(&io.loops[1].open_connections, 4);
        Metrics::add(&io.loops[1].bytes_in, 100);
        Metrics::inc(&io.frames_binary);
        io.decode_latency.record_ns(500);
        assert_eq!(io.open_connections(), 7);
        let j = crate::util::json::parse(&io.to_json().to_string()).unwrap();
        assert_eq!(j.get("io_threads").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("open_connections").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("bytes_in").unwrap().as_usize(), Some(100));
        assert_eq!(j.get("frames_binary").unwrap().as_usize(), Some(1));
        assert_eq!(
            j.get("decode_latency").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn in_flight_never_underflows() {
        let mut f = MetricsFrame { responses: 5, errors: 2, requests: 6, ..Default::default() };
        assert_eq!(f.in_flight(), 0); // racy relaxed reads can transiently invert
        f.requests = 10;
        assert_eq!(f.in_flight(), 3);
    }

    fn json_keys(j: &Json) -> Vec<String> {
        match j {
            Json::Obj(m) => m.keys().cloned().collect(),
            _ => panic!("not an object"),
        }
    }

    #[test]
    fn gateway_metrics_schema_is_traffic_independent() {
        // a zeroed sink and a busy sink must serialize the same key set at
        // every level — /v1/stats consumers see one schema regardless of
        // which routes have seen traffic (or whether a gateway runs at all)
        let zero = GatewayMetrics::default();
        let busy = GatewayMetrics::default();
        Metrics::inc(&busy.accepted);
        busy.observe(GatewayRoute::Hull, 200, 128, 4096, 12_000);
        busy.observe(GatewayRoute::SessionHull, 404, 0, 64, 5_000);
        busy.observe(GatewayRoute::Hull, 503, 16, 90, 1_000);
        let zj = zero.to_json();
        let bj = busy.to_json();
        assert_eq!(json_keys(&zj), json_keys(&bj));
        assert_eq!(
            json_keys(zj.get("routes").unwrap()),
            json_keys(bj.get("routes").unwrap())
        );
        for r in GatewayRoute::ALL {
            let z = zj.get("routes").unwrap().get(r.name()).unwrap();
            let b = bj.get("routes").unwrap().get(r.name()).unwrap();
            assert_eq!(json_keys(z), json_keys(b), "{}", r.name());
        }
        let hull = bj.get("routes").unwrap().get("POST /v1/hull").unwrap();
        assert_eq!(hull.get("requests").unwrap().as_usize(), Some(2));
        assert_eq!(hull.get("status_2xx").unwrap().as_usize(), Some(1));
        assert_eq!(hull.get("status_5xx").unwrap().as_usize(), Some(1));
        assert_eq!(hull.get("bytes_out").unwrap().as_usize(), Some(4186));
        // round-trips through the parser like every STATS payload
        crate::util::json::parse(&bj.to_string()).unwrap();
    }
}
