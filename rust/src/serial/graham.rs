//! Graham scan (full hull via polar sort around the bottom-most point).
//! Secondary serial baseline for E4; also handles unsorted input.

use crate::geometry::point::Point;
use crate::geometry::predicates::{orient2d, orient2d_value, Orientation};

/// Full convex hull, CCW order starting at the bottom-most (then leftmost)
/// point.  Handles arbitrary (unsorted) input; collinear points dropped.
pub fn convex_hull(points: &[Point]) -> Vec<Point> {
    let n = points.len();
    if n <= 2 {
        let mut v = points.to_vec();
        v.dedup_by(|a, b| a == b);
        return v;
    }
    let pivot = *points
        .iter()
        .min_by(|a, b| {
            a.y.partial_cmp(&b.y)
                .unwrap()
                .then(a.x.partial_cmp(&b.x).unwrap())
        })
        .unwrap();

    let mut rest: Vec<Point> = points.iter().copied().filter(|&p| p != pivot).collect();
    // polar sort around pivot; ties (collinear with pivot) by distance
    rest.sort_by(|&a, &b| {
        match orient2d(pivot, a, b) {
            Orientation::Left => std::cmp::Ordering::Less,
            Orientation::Right => std::cmp::Ordering::Greater,
            Orientation::Straight => {
                let da = (a.x - pivot.x).abs() + (a.y - pivot.y).abs();
                let db = (b.x - pivot.x).abs() + (b.y - pivot.y).abs();
                da.partial_cmp(&db).unwrap()
            }
        }
    });

    let mut stack = vec![pivot];
    for p in rest {
        while stack.len() >= 2
            && orient2d_value(stack[stack.len() - 2], stack[stack.len() - 1], p) <= 0.0
        {
            stack.pop();
        }
        stack.push(p);
    }
    stack
}

/// Extract the upper chain (left-to-right) from a CCW hull polygon, for
/// comparison against the hood pipelines.
pub fn upper_chain(hull_ccw: &[Point]) -> Vec<Point> {
    if hull_ccw.len() <= 2 {
        let mut v = hull_ccw.to_vec();
        v.sort_by(|a, b| a.x.partial_cmp(&b.x).unwrap());
        return v;
    }
    let leftmost = (0..hull_ccw.len())
        .min_by(|&i, &j| {
            let (a, b) = (hull_ccw[i], hull_ccw[j]);
            a.x.partial_cmp(&b.x).unwrap().then(b.y.partial_cmp(&a.y).unwrap())
        })
        .unwrap();
    let rightmost = (0..hull_ccw.len())
        .max_by(|&i, &j| {
            let (a, b) = (hull_ccw[i], hull_ccw[j]);
            a.x.partial_cmp(&b.x).unwrap().then(b.y.partial_cmp(&a.y).unwrap())
        })
        .unwrap();
    // CCW polygon: walk from rightmost to leftmost gives the upper chain
    let mut chain = Vec::new();
    let n = hull_ccw.len();
    let mut i = rightmost;
    loop {
        chain.push(hull_ccw[i]);
        if i == leftmost {
            break;
        }
        i = (i + 1) % n;
    }
    chain.reverse();
    chain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::serial::monotone_chain;
    use crate::util::rng::Rng;

    #[test]
    fn square_hull() {
        let pts: Vec<Point> = [(0., 0.), (1., 0.), (1., 1.), (0., 1.), (0.5, 0.5)]
            .iter()
            .map(|&(x, y)| Point::new(x, y))
            .collect();
        let hull = convex_hull(&pts);
        assert_eq!(hull.len(), 4);
        assert!(!hull.contains(&Point::new(0.5, 0.5)));
    }

    #[test]
    fn upper_chain_matches_monotone_chain() {
        for dist in Distribution::ALL {
            let pts = generate(dist, 64, 5);
            let hull = convex_hull(&pts);
            let upper = upper_chain(&hull);
            let want = monotone_chain::upper_hull(&pts);
            assert_eq!(upper, want, "{}", dist.name());
        }
    }

    #[test]
    fn unsorted_input_ok() {
        let mut rng = Rng::new(4);
        let mut pts = generate(Distribution::Disk, 100, 8);
        rng.shuffle(&mut pts);
        let hull = convex_hull(&pts);
        let mut sorted = pts.clone();
        crate::geometry::point::sort_by_x(&mut sorted);
        assert_eq!(upper_chain(&hull), monotone_chain::upper_hull(&sorted));
    }
}
