//! End-to-end L3↔L2 integration: load real AOT artifacts, execute them on
//! the PJRT CPU client, and compare against the rust-native Wagener
//! pipeline and the serial baseline.  Requires `make artifacts`; tests
//! SKIP (pass vacuously, with a stderr note) when the artifacts or the
//! PJRT runtime are absent.

use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::hull_check::check_upper_hull;
use wagener_hull::runtime::{ArtifactRegistry, HullExecutor};
use wagener_hull::serial::monotone_chain;
use wagener_hull::wagener;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn executor() -> Option<HullExecutor> {
    let reg = match ArtifactRegistry::load(artifacts_dir()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            return None;
        }
    };
    match HullExecutor::new(reg) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP (PJRT runtime unavailable): {e:#}");
            None
        }
    }
}

#[test]
fn hood_artifact_matches_serial() {
    let Some(exe) = executor() else { return };
    let meta = exe.registry().get("hood_n64").unwrap().clone();
    for dist in [Distribution::UniformSquare, Distribution::Parabola, Distribution::Valley] {
        for seed in 0..3 {
            let pts = generate(dist, 64, seed);
            let got = exe.run_hood(&meta, &pts).unwrap();
            let want = monotone_chain::upper_hull(&pts);
            assert_eq!(got, want, "{} seed {seed}", dist.name());
        }
    }
}

#[test]
fn hood_artifact_accepts_padding() {
    let Some(exe) = executor() else { return };
    let meta = exe.registry().get("hood_n64").unwrap().clone();
    for m in [1usize, 2, 7, 33, 64] {
        let pts = generate(Distribution::Disk, m, 9);
        let got = exe.run_hood(&meta, &pts).unwrap();
        assert_eq!(got, monotone_chain::upper_hull(&pts), "m={m}");
    }
}

#[test]
fn hull_artifact_batch1() {
    let Some(exe) = executor() else { return };
    let meta = exe.registry().get("hull_n128_b1").unwrap().clone();
    let pts = generate(Distribution::Circle, 100, 4);
    let out = exe.run_hull(&meta, &[pts.clone()]).unwrap();
    assert_eq!(out.len(), 1);
    let (up, lo) = &out[0];
    let (su, sl) = monotone_chain::full_hull(&pts);
    assert_eq!(up, &su);
    assert_eq!(lo, &sl);
    check_upper_hull(&pts, up).unwrap();
}

#[test]
fn hull_artifact_batch8_mixed_sizes() {
    let Some(exe) = executor() else { return };
    let meta = exe.registry().get("hull_n64_b8").unwrap().clone();
    let reqs: Vec<Vec<_>> = (0..5)
        .map(|k| generate(Distribution::ALL[k % 7], 10 + 9 * k, k as u64))
        .collect();
    let out = exe.run_hull(&meta, &reqs).unwrap();
    assert_eq!(out.len(), 5);
    for (req, (up, lo)) in reqs.iter().zip(&out) {
        let (su, sl) = monotone_chain::full_hull(req);
        assert_eq!(up, &su);
        assert_eq!(lo, &sl);
    }
}

#[test]
fn pjrt_matches_rust_native_wagener() {
    // three implementations of the same algorithm agree bit-for-bit on
    // f32-quantized inputs
    let Some(exe) = executor() else { return };
    let meta = exe.registry().get("hull_n256_b1").unwrap().clone();
    for seed in 0..3 {
        let pts = generate(Distribution::UniformSquare, 200, seed);
        let pjrt = exe.run_hull(&meta, &[pts.clone()]).unwrap();
        let (nu, nl) = wagener::full_hull(&pts);
        assert_eq!(pjrt[0].0, nu, "upper seed {seed}");
        assert_eq!(pjrt[0].1, nl, "lower seed {seed}");
    }
}

#[test]
fn auto_routing_selects_size_class() {
    let Some(exe) = executor() else { return };
    let reqs = vec![generate(Distribution::Disk, 90, 2)];
    let out = exe.hull_auto(&reqs).unwrap();
    let (su, sl) = monotone_chain::full_hull(&reqs[0]);
    assert_eq!(out[0].0, su);
    assert_eq!(out[0].1, sl);
}

#[test]
fn compile_cache_reused() {
    let Some(exe) = executor() else { return };
    let meta = exe.registry().get("hull_n64_b1").unwrap().clone();
    let pts = generate(Distribution::UniformSquare, 30, 1);
    for _ in 0..3 {
        exe.run_hull(&meta, &[pts.clone()]).unwrap();
    }
    let stats = exe.stats();
    assert_eq!(stats.compiles, 1);
    assert_eq!(stats.executions, 3);
    assert_eq!(stats.requests, 3);
}

#[test]
fn jnp_ablation_twin_matches_pallas_artifact() {
    let Some(exe) = executor() else { return };
    let pallas = exe.registry().get("hood_n256").unwrap().clone();
    let jnp = exe.registry().get("hood_jnp_n256").unwrap().clone();
    let pts = generate(Distribution::Clusters(5), 256, 6);
    let a = exe.run_hood(&pallas, &pts).unwrap();
    let b = exe.run_hood(&jnp, &pts).unwrap();
    assert_eq!(a, b);
}
