//! Threaded TCP server: one accept loop, one handler thread per
//! connection, all sharing the [`Engine`] facade — one-shot requests are
//! routed to the cheapest coordinator shard, session verbs to their sid's
//! pinned shard (thread-based substitute for the usual async runtime;
//! connections are long-lived and few, work is CPU-bound, so
//! thread-per-connection is the right shape here).
//!
//! Handler threads are *tracked*, not detached: `ServerHandle::stop`
//! shuts every live connection's socket down and joins the handlers, so
//! nothing races an engine shutdown that follows.

use std::io::{BufReader, BufWriter};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::{Coordinator, HullRequest};
use crate::engine::Engine;
use crate::log_info;
use crate::stream::{SessionRegistry, StreamConfig};

use super::proto::{self, ProtoError, Request, Response, SessionVerb};

/// Server knobs (config file: `[server]`).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// bind address, e.g. "127.0.0.1:7878"; port 0 picks a free port.
    pub addr: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:7878".into() }
    }
}

/// A live connection: the handler thread plus a socket handle the accept
/// loop keeps so `stop` can unblock a handler parked in `read_line`.
struct ConnSlot {
    id: u64,
    handle: JoinHandle<()>,
    stream: TcpStream,
}

/// Shared connection registry.  The accept loop holds the mutex across
/// the handler spawn, so a slot is always registered before its handler
/// can look for it; handlers then remove their own slot on exit
/// (dropping the tracked stream clone immediately, so a closed client's
/// socket never lingers in CLOSE_WAIT waiting for the next accept), and
/// `stop` drains and joins whatever is still live.
#[derive(Default)]
struct ConnRegistry {
    conns: Mutex<Vec<ConnSlot>>,
    /// active-connection *gauge*: incremented at accept, decremented when
    /// the handler exits (it used to be a monotonically increasing
    /// counter mislabeled as "connections").
    active: AtomicU64,
    next_id: AtomicU64,
}

/// Handle to a running server (shutdown on drop).
pub struct ServerHandle {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    registry: Arc<ConnRegistry>,
    engine: Arc<Engine>,
}

impl ServerHandle {
    /// Currently open connections (gauge, not a lifetime total).
    pub fn active_connections(&self) -> u64 {
        self.registry.active.load(Ordering::Relaxed)
    }

    /// The engine this server serves (shards, registries, metrics).
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Shard 0's session registry — meaningful only for 1-shard engines
    /// (the [`serve`] / [`serve_with_sessions`] compatibility paths).
    /// Sharded callers should use [`ServerHandle::engine`] and address
    /// shards explicitly (`sweep_now` there sweeps every shard).
    pub fn sessions(&self) -> &Arc<SessionRegistry> {
        self.engine.shard_registry(0)
    }

    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop awake
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        // unblock handlers parked on reads, then join every one of them:
        // after stop() returns, no handler can race a coordinator shutdown.
        // Read-side only: a handler mid-request still flushes its response
        // (the coordinator drain guarantee) and exits on the next EOF.
        let drained: Vec<ConnSlot> = match self.registry.conns.lock() {
            Ok(mut conns) => conns.drain(..).collect(),
            Err(_) => return,
        };
        for slot in &drained {
            let _ = slot.stream.shutdown(Shutdown::Read);
        }
        for slot in drained {
            let _ = slot.handle.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Deprecated thin wrapper: start serving one `coordinator` on
/// `cfg.addr`.  Streaming sessions get a default-configured registry
/// sharing the coordinator's metrics.  New code should build an
/// [`Engine`] and call [`serve_engine`]; this wraps the coordinator as a
/// 1-shard engine, which is bit- and protocol-identical.
pub fn serve(coordinator: Arc<Coordinator>, cfg: &ServerConfig) -> std::io::Result<ServerHandle> {
    let stream_cfg = StreamConfig::default().clamp_threshold_to(coordinator.max_points());
    let sessions = Arc::new(SessionRegistry::new(stream_cfg, coordinator.metrics.clone()));
    serve_with_sessions(coordinator, sessions, cfg)
}

/// Deprecated thin wrapper: [`serve`] with an explicitly configured
/// session registry (clamp the threshold with
/// [`StreamConfig::clamp_threshold_to`] — a threshold above the backend's
/// request cap can never merge).  New code should build an [`Engine`] and
/// call [`serve_engine`].
pub fn serve_with_sessions(
    coordinator: Arc<Coordinator>,
    sessions: Arc<SessionRegistry>,
    cfg: &ServerConfig,
) -> std::io::Result<ServerHandle> {
    serve_engine(Arc::new(Engine::single(coordinator, sessions)), cfg)
}

/// Start serving `engine` on `cfg.addr` (non-blocking; returns a handle).
/// One-shot requests route to the cheapest shard; session verbs follow
/// their sid's shard; `STATS` returns the merged aggregate plus a
/// `per_shard` array and the `active_connections` gauge.
pub fn serve_engine(engine: Arc<Engine>, cfg: &ServerConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local_addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let registry = Arc::new(ConnRegistry::default());
    log_info!(
        "serving on {local_addr} (backend={} shards={})",
        engine.backend_name(),
        engine.shard_count()
    );

    let stop2 = stop.clone();
    let reg2 = registry.clone();
    let engine2 = engine.clone();
    let accept_thread = std::thread::Builder::new()
        .name("hull-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let eng = engine2.clone();
                        let reg = reg2.clone();
                        let tracked = match s.try_clone() {
                            Ok(t) => t,
                            Err(_) => continue, // dead socket; skip it
                        };
                        reg.active.fetch_add(1, Ordering::Relaxed);
                        let conn_id = reg.next_id.fetch_add(1, Ordering::Relaxed);
                        let reg_in = reg.clone();
                        // hold the registry lock across the spawn: the
                        // slot is pushed before the handler can possibly
                        // look for it, so the self-reap below always
                        // finds it — an instantly-exiting handler just
                        // blocks on the mutex for the push's duration
                        let Ok(mut conns) = reg.conns.lock() else {
                            // poisoned (a handler panicked mid-reap):
                            // tracking is gone; refuse the connection
                            reg.active.fetch_sub(1, Ordering::Relaxed);
                            continue;
                        };
                        let spawned = std::thread::Builder::new()
                            .name("hull-conn".into())
                            .spawn(move || {
                                handle_connection(s, eng, &reg_in.active);
                                reg_in.active.fetch_sub(1, Ordering::Relaxed);
                                // self-reap: drop the tracked stream clone
                                // now, not at the next accept — only the
                                // coordinator-free tail of this thread
                                // outlives the slot, so `stop` loses
                                // nothing by not joining it.  Dropping our
                                // own JoinHandle merely detaches.
                                if let Ok(mut conns) = reg_in.conns.lock() {
                                    if let Some(i) =
                                        conns.iter().position(|c| c.id == conn_id)
                                    {
                                        conns.swap_remove(i);
                                    }
                                }
                            });
                        match spawned {
                            Ok(handle) => {
                                conns.push(ConnSlot { id: conn_id, handle, stream: tracked });
                            }
                            Err(e) => {
                                reg.active.fetch_sub(1, Ordering::Relaxed);
                                log_info!("spawn error: {e}");
                            }
                        }
                    }
                    Err(e) => {
                        log_info!("accept error: {e}");
                    }
                }
            }
        })?;

    Ok(ServerHandle { local_addr, stop, accept_thread: Some(accept_thread), registry, engine })
}

fn handle_connection(stream: TcpStream, engine: Arc<Engine>, active: &AtomicU64) {
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = BufWriter::new(stream);
    loop {
        let req = match proto::read_request(&mut reader) {
            Ok(r) => r,
            Err(ProtoError::Eof) => break,
            Err(e) => {
                // echo the failed frame's id when the header parsed, so
                // id-correlating clients can still match the failure
                // (session frames echo under their own verb)
                let resp = match &e {
                    ProtoError::TooManyPoints { id, session: false, .. } => {
                        Response::HullErr { id: *id, message: e.to_string() }
                    }
                    ProtoError::TooManyPoints { id, session: true, .. } => {
                        Response::SessionErr {
                            verb: SessionVerb::Add,
                            id: *id,
                            message: e.to_string(),
                        }
                    }
                    _ => Response::MalformedErr { id: e.frame_id(), message: e.to_string() },
                };
                let _ = proto::write_response(&mut writer, &resp);
                break;
            }
        };
        match req {
            Request::Quit => break,
            Request::Ping => {
                if proto::write_response(&mut writer, &Response::Pong).is_err() {
                    break;
                }
            }
            Request::Stats => {
                // merged aggregate + per_shard array, plus the server's
                // connection gauge (engine-global, read exactly once)
                let snap = engine.stats(Some(active.load(Ordering::Relaxed))).0.to_string();
                if proto::write_response(&mut writer, &Response::Stats(snap)).is_err() {
                    break;
                }
            }
            Request::Hull { id, points } => {
                let reply = engine.submit(HullRequest { id, points });
                let resp = match reply.recv() {
                    Ok(Ok(h)) => Response::Hull {
                        id,
                        upper: h.upper,
                        lower: h.lower,
                        backend: h.backend.to_string(),
                        queue_ns: h.queue_ns,
                        exec_ns: h.exec_ns,
                    },
                    Ok(Err(e)) => Response::HullErr { id, message: e.to_string() },
                    Err(_) => Response::HullErr { id, message: "coordinator gone".into() },
                };
                if proto::write_response(&mut writer, &resp).is_err() {
                    break;
                }
            }
            Request::SessionOpen { id } => {
                let resp = match engine.session_open() {
                    Ok(sid) => Response::SessionOpened { id, sid },
                    Err(e) => Response::SessionErr {
                        verb: SessionVerb::Open,
                        id,
                        message: e.to_string(),
                    },
                };
                if proto::write_response(&mut writer, &resp).is_err() {
                    break;
                }
            }
            Request::SessionAdd { sid, points } => {
                let resp = match engine.session_add(sid, &points) {
                    Ok(o) => Response::SessionAdded {
                        sid,
                        absorbed: o.absorbed,
                        pending: o.pending as u64,
                        epoch: o.epoch,
                    },
                    Err(e) => Response::SessionErr {
                        verb: SessionVerb::Add,
                        id: sid,
                        message: e.to_string(),
                    },
                };
                if proto::write_response(&mut writer, &resp).is_err() {
                    break;
                }
            }
            Request::SessionHull { sid } => {
                let resp = match engine.session_hull(sid) {
                    Ok(s) => Response::SessionHull {
                        sid,
                        epoch: s.epoch,
                        upper: s.upper,
                        lower: s.lower,
                    },
                    Err(e) => Response::SessionErr {
                        verb: SessionVerb::Hull,
                        id: sid,
                        message: e.to_string(),
                    },
                };
                if proto::write_response(&mut writer, &resp).is_err() {
                    break;
                }
            }
            Request::SessionClose { sid } => {
                let resp = match engine.session_close(sid) {
                    Ok(()) => Response::SessionClosed { sid },
                    Err(e) => Response::SessionErr {
                        verb: SessionVerb::Close,
                        id: sid,
                        message: e.to_string(),
                    },
                };
                if proto::write_response(&mut writer, &resp).is_err() {
                    break;
                }
            }
        }
    }
    let _ = peer;
}
