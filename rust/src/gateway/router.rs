//! Typed routing: method + path patterns → handlers, with extraction of
//! path parameters (`{sid}`) and query parameters into native types.
//!
//! A handler is a plain `fn(&C, &HttpRequest, &PathParams) ->
//! Result<HttpResponse, HttpResponse>` — `Err` is still a well-formed
//! response, it just lets handlers bail with `?`-style early returns via
//! [`err!`].  The [`routes!`] macro builds the table declaratively; each
//! entry carries its [`GatewayRoute`] tag so the dispatch loop can record
//! per-route metrics and logs without re-parsing the path.

use super::http::{HttpRequest, HttpResponse, Method};
use crate::coordinator::GatewayRoute;

/// One segment of a route pattern.
enum Seg {
    Lit(&'static str),
    Param(&'static str),
}

/// Path parameters captured during a successful match.
pub struct PathParams {
    vals: Vec<(&'static str, String)>,
}

impl PathParams {
    /// Typed extraction: the named `{param}` as a `u64`, or a ready-made
    /// 400 response naming the offending parameter.
    pub fn u64(&self, name: &str) -> Result<u64, HttpResponse> {
        let raw = self
            .vals
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
            .unwrap_or("");
        raw.parse().map_err(|_| {
            HttpResponse::error(
                400,
                "bad-path-parameter",
                &format!("path parameter {{{name}}} must be an unsigned integer, got {raw:?}"),
            )
        })
    }

    fn raw(&self, name: &str) -> Option<&str> {
        self.vals.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }
}

/// Typed query extraction shared by handlers: `Ok(None)` when absent,
/// `Err(400)` when present but unparseable.
pub fn query_u64(req: &HttpRequest, name: &str) -> Result<Option<u64>, HttpResponse> {
    query_parsed(req, name)
}

pub fn query_u32(req: &HttpRequest, name: &str) -> Result<Option<u32>, HttpResponse> {
    query_parsed(req, name)
}

pub fn query_usize(req: &HttpRequest, name: &str) -> Result<Option<usize>, HttpResponse> {
    query_parsed(req, name)
}

fn query_parsed<T: std::str::FromStr>(
    req: &HttpRequest,
    name: &str,
) -> Result<Option<T>, HttpResponse> {
    match req.query(name) {
        None => Ok(None),
        Some(raw) => raw.parse().map(Some).map_err(|_| {
            HttpResponse::error(
                400,
                "bad-query-parameter",
                &format!("query parameter {name} must be an unsigned integer, got {raw:?}"),
            )
        }),
    }
}

pub type Handler<C> = fn(&C, &HttpRequest, &PathParams) -> Result<HttpResponse, HttpResponse>;

struct RouteEntry<C> {
    method: Method,
    segs: Vec<Seg>,
    route: GatewayRoute,
    handler: Handler<C>,
}

/// What the dispatch loop needs back: the response plus the route tag for
/// metrics and, when the path carried a `{sid}`, the session id for
/// shard attribution in the request log.
pub struct Dispatched {
    pub route: GatewayRoute,
    pub sid: Option<u64>,
    pub resp: HttpResponse,
}

pub struct Router<C> {
    routes: Vec<RouteEntry<C>>,
}

impl<C> Default for Router<C> {
    fn default() -> Self {
        Router { routes: Vec::new() }
    }
}

impl<C> Router<C> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `pattern` (e.g. `/v1/sessions/{sid}/hull`) for `method`.
    pub fn add(
        &mut self,
        method: Method,
        pattern: &'static str,
        route: GatewayRoute,
        handler: Handler<C>,
    ) {
        let segs = pattern
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| match s.strip_prefix('{').and_then(|s| s.strip_suffix('}')) {
                Some(name) => Seg::Param(name),
                None => Seg::Lit(s),
            })
            .collect();
        self.routes.push(RouteEntry { method, segs, route, handler });
    }

    fn match_path(entry: &RouteEntry<C>, path: &[&str]) -> Option<PathParams> {
        if entry.segs.len() != path.len() {
            return None;
        }
        let mut vals = Vec::new();
        for (seg, got) in entry.segs.iter().zip(path) {
            match seg {
                Seg::Lit(want) => {
                    if want != got {
                        return None;
                    }
                }
                Seg::Param(name) => vals.push((*name, got.to_string())),
            }
        }
        Some(PathParams { vals })
    }

    /// Route and run one request.  Misses produce the uniform JSON error
    /// body: 405 when the path exists under a different method, 404
    /// otherwise.
    pub fn dispatch(&self, ctx: &C, req: &HttpRequest) -> Dispatched {
        let path: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        let mut other_method = false;
        for entry in &self.routes {
            let Some(params) = Self::match_path(entry, &path) else {
                continue;
            };
            if entry.method != req.method {
                other_method = true;
                continue;
            }
            let sid = params.raw("sid").and_then(|v| v.parse().ok());
            let resp = match (entry.handler)(ctx, req, &params) {
                Ok(r) | Err(r) => r,
            };
            return Dispatched { route: entry.route, sid, resp };
        }
        let resp = if other_method {
            HttpResponse::error(
                405,
                "method-not-allowed",
                &format!("{} is not served at {}", req.method.word(), req.path),
            )
        } else {
            HttpResponse::error(404, "unknown-route", &format!("no route matches {}", req.path))
        };
        Dispatched { route: GatewayRoute::Other, sid: None, resp }
    }
}

/// Build a [`Router`] from a declarative table:
///
/// ```ignore
/// let router = routes! {
///     Post "/v1/hull"                    => GatewayRoute::Hull,        h_hull;
///     Get  "/v1/sessions/{sid}/hull"     => GatewayRoute::SessionHull, h_session_hull;
/// };
/// ```
macro_rules! routes {
    ($($method:ident $pattern:literal => $route:expr, $handler:expr);* $(;)?) => {{
        let mut r = $crate::gateway::router::Router::new();
        $(r.add($crate::gateway::http::Method::$method, $pattern, $route, $handler);)*
        r
    }};
}
pub(crate) use routes;

/// `Ok(200)` JSON object response from `"key" => value` pairs.
macro_rules! ok {
    ($($k:literal => $v:expr),* $(,)?) => {
        Ok($crate::gateway::http::HttpResponse::json(
            200,
            $crate::util::json::Json::obj(vec![$(($k, $v)),*]),
        ))
    };
}
pub(crate) use ok;

/// `Err` early-exit with the uniform error body: `return err!(status,
/// code, message)`.
macro_rules! err {
    ($status:expr, $code:expr, $msg:expr) => {
        Err($crate::gateway::http::HttpResponse::error($status, $code, &$msg))
    };
}
pub(crate) use err;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::proto::Decoded;
    use crate::util::json::Json;

    struct Ctx;

    fn h_echo_sid(_: &Ctx, _: &HttpRequest, p: &PathParams) -> Result<HttpResponse, HttpResponse> {
        let sid = p.u64("sid")?;
        ok!("sid" => Json::Num(sid as f64))
    }

    fn h_fail(_: &Ctx, _: &HttpRequest, _: &PathParams) -> Result<HttpResponse, HttpResponse> {
        err!(503, "overloaded", "try later")
    }

    fn table() -> Router<Ctx> {
        routes! {
            Get    "/v1/sessions/{sid}/hull" => GatewayRoute::SessionHull, h_echo_sid;
            Delete "/v1/sessions/{sid}"      => GatewayRoute::SessionClose, h_fail;
        }
    }

    fn req(method: Method, target: &str) -> HttpRequest {
        let wire = format!("{} {} HTTP/1.1\r\n\r\n", method.word(), target);
        match crate::gateway::http::decode_request(wire.as_bytes(), 1 << 20).unwrap() {
            Decoded::Frame(r, _) => r,
            Decoded::Need(_) => panic!("incomplete test request"),
        }
    }

    #[test]
    fn matches_and_extracts_typed_params() {
        let d = table().dispatch(&Ctx, &req(Method::Get, "/v1/sessions/42/hull"));
        assert_eq!(d.route, GatewayRoute::SessionHull);
        assert_eq!(d.sid, Some(42));
        assert_eq!(d.resp.status, 200);
        assert_eq!(String::from_utf8(d.resp.body).unwrap(), "{\"sid\":42}");
    }

    #[test]
    fn bad_path_param_is_a_400_not_a_handler_panic() {
        let d = table().dispatch(&Ctx, &req(Method::Get, "/v1/sessions/banana/hull"));
        assert_eq!(d.resp.status, 400);
        assert!(String::from_utf8(d.resp.body).unwrap().contains("bad-path-parameter"));
    }

    #[test]
    fn unknown_path_is_404_wrong_method_is_405() {
        let d = table().dispatch(&Ctx, &req(Method::Get, "/nope"));
        assert_eq!(d.resp.status, 404);
        assert_eq!(d.route, GatewayRoute::Other);
        let d = table().dispatch(&Ctx, &req(Method::Post, "/v1/sessions/7"));
        assert_eq!(d.resp.status, 405);
    }

    #[test]
    fn err_macro_flows_through_as_a_response() {
        let d = table().dispatch(&Ctx, &req(Method::Delete, "/v1/sessions/7"));
        assert_eq!(d.resp.status, 503);
        assert_eq!(d.sid, Some(7));
        assert!(String::from_utf8(d.resp.body).unwrap().contains("overloaded"));
    }

    #[test]
    fn query_extraction_is_typed() {
        let r = req(Method::Get, "/v1/sessions/1/hull?epoch=9&limit=abc");
        assert_eq!(query_u64(&r, "epoch").unwrap(), Some(9));
        assert_eq!(query_u64(&r, "cursorless").unwrap(), None);
        let e = query_usize(&r, "limit").unwrap_err();
        assert_eq!(e.status, 400);
    }
}
